//! The live observability plane: per-flow / per-tenant / per-engine
//! counters, tick-indexed series, mergeable latency histograms, and the
//! fault-era + recovery accounting that `SystemReport` derives its
//! `FaultReport`s from.
//!
//! Everything here updates from existing simulation events — completions,
//! drops, and the periodic `ControlTick` — so the plane adds **zero**
//! events to the schedule and (after construction) **zero** allocations to
//! the hot path. All series are indexed by control tick (`now /
//! control_period`), never wall clock, which is what lets the snapshot
//! digest be asserted byte-identical across event-queue disciplines.

use crate::flow::Slo;
use crate::metrics::hist::WindowedHistogram;
use crate::metrics::Histogram;
use crate::shaping::ShapeMode;
use crate::util::units::{Time, SECONDS};

use super::series::SeriesRing;

/// A flow counts as recovered from a fault once one full post-fault
/// control-period window carries at least this fraction of its SLO rate.
/// (Paper §6: recovery-to-SLO; moved here from `system::engine`.)
pub const RECOVERY_FRACTION: f64 = 0.95;

/// Sentinel stored in gauge series when the window had no value (no SLO
/// target, empty latency window, zero span). Exporters render it as
/// "absent" rather than a number.
pub const GAUGE_NONE: u64 = u64::MAX;

/// Names of the per-flow signals, in the order they are serialized by the
/// binary dump and folded into the digest.
pub const FLOW_SIGNALS: [&str; 7] = [
    "bytes",
    "ops",
    "dropped",
    "queue_depth",
    "attainment_ppm",
    "p99_ps",
    "directives",
];

/// Per-flow tick-indexed series. Counters (`bytes`, `ops`, `dropped`,
/// `directives`) sample the *cumulative* total at each tick — monotone by
/// construction, as Prometheus counters require. Gauges sample the value
/// of the control window that just closed.
#[derive(Debug, Clone)]
pub struct FlowSeries {
    /// Flow id (stable registration order).
    pub flow: usize,
    /// Owning tenant / VM id.
    pub vm: usize,
    /// Engine (shaper-tree root) the flow hangs off.
    pub engine: usize,
    /// Cumulative post-warmup bytes completed.
    pub bytes: SeriesRing,
    /// Cumulative post-warmup operations completed.
    pub ops: SeriesRing,
    /// Cumulative drops.
    pub dropped: SeriesRing,
    /// Shaper-queue depth + in-flight ops at the tick (gauge).
    pub queue_depth: SeriesRing,
    /// Window attainment in parts-per-million (gauge; [`GAUGE_NONE`] when
    /// the window had no measurable attainment).
    pub attainment_ppm: SeriesRing,
    /// Window p99 latency in picoseconds (gauge; [`GAUGE_NONE`] when the
    /// window saw no completions).
    pub p99_ps: SeriesRing,
    /// Cumulative control-plane directives applied to this flow.
    pub directives: SeriesRing,
}

impl FlowSeries {
    fn new(flow: usize, vm: usize, engine: usize, cap: usize) -> Self {
        FlowSeries {
            flow,
            vm,
            engine,
            bytes: SeriesRing::new(cap),
            ops: SeriesRing::new(cap),
            dropped: SeriesRing::new(cap),
            queue_depth: SeriesRing::new(cap),
            attainment_ppm: SeriesRing::new(cap),
            p99_ps: SeriesRing::new(cap),
            directives: SeriesRing::new(cap),
        }
    }

    /// The signal rings in [`FLOW_SIGNALS`] order.
    pub fn signals(&self) -> [&SeriesRing; 7] {
        [
            &self.bytes,
            &self.ops,
            &self.dropped,
            &self.queue_depth,
            &self.attainment_ppm,
            &self.p99_ps,
            &self.directives,
        ]
    }
}

/// Tenant-level rollup: counters, a tick series, and the merged latency
/// histogram of every completion by the tenant's flows.
#[derive(Debug, Clone)]
pub struct TenantObs {
    /// Tenant / VM id.
    pub vm: usize,
    /// Cumulative post-warmup bytes across the tenant's flows.
    pub bytes: u64,
    /// Cumulative post-warmup ops across the tenant's flows.
    pub ops: u64,
    /// Merged completion-latency histogram (ps).
    pub lat: Histogram,
    /// Cumulative bytes sampled per tick.
    pub bytes_series: SeriesRing,
    /// Cumulative ops sampled per tick.
    pub ops_series: SeriesRing,
}

/// Engine-level rollup (one per shaper tree, plus one trailing slot for
/// storage-path flows).
#[derive(Debug, Clone)]
pub struct EngineObs {
    /// Engine index (== shaper-tree index; the last slot is storage).
    pub engine: usize,
    /// Cumulative post-warmup bytes through the engine.
    pub bytes: u64,
    /// Cumulative post-warmup ops through the engine.
    pub ops: u64,
    /// Merged completion-latency histogram (ps) — the tenant histograms of
    /// this engine folded up one more level.
    pub lat: Histogram,
    /// Cumulative bytes sampled per tick.
    pub bytes_series: SeriesRing,
}

/// Per-flow fault-era tracker. Eras are delimited by the union fault
/// window `(start, end)`; because completion times are monotone, each
/// boundary is crossed at most once and the cumulative counters can be
/// snapshotted exactly at the crossing.
#[derive(Debug, Clone)]
struct EraTrack {
    /// Era of the most recent completion (0 = pre, 1 = during, 2 = post).
    era: usize,
    /// Cumulative (bytes, ops) at the 0→1 and 1→2 boundaries.
    marks: [(u64, u64); 2],
    /// Completion latencies bucketed per era.
    lat: WindowedHistogram,
}

impl EraTrack {
    fn new() -> Self {
        EraTrack {
            era: 0,
            marks: [(0, 0); 2],
            lat: WindowedHistogram::new(3),
        }
    }

    /// Advance to `era`, snapshotting the cumulative counters at each
    /// boundary crossed. `bytes`/`ops` are the totals *before* the
    /// completion that triggered the advance (it belongs to the new era).
    fn advance_to(&mut self, era: usize, bytes: u64, ops: u64) {
        while self.era < era {
            self.marks[self.era] = (bytes, ops);
            self.era += 1;
        }
    }

    /// Per-era (bytes, ops) derived from the boundary snapshots and the
    /// final totals. Boundaries never crossed collapse to the final total,
    /// leaving later eras empty — exactly right when a flow saw no
    /// completions there.
    fn eras(&self, total_bytes: u64, total_ops: u64) -> [(u64, u64); 3] {
        let b0 = if self.era > 0 { self.marks[0] } else { (total_bytes, total_ops) };
        let b1 = if self.era > 1 { self.marks[1] } else { (total_bytes, total_ops) };
        [
            b0,
            (b1.0 - b0.0, b1.1 - b0.1),
            (total_bytes - b1.0, total_ops - b1.1),
        ]
    }
}

/// Post-fault recovery tracker (semantics identical to the pre-obs
/// engine-local accounting): fixed control-period windows starting at
/// `max(fault_end, arrived_at)`, recovered once a full window achieves
/// `RECOVERY_FRACTION` of the SLO rate.
#[derive(Debug, Clone, Copy, Default)]
struct RecoveryTrack {
    win_start: Time,
    bytes: u64,
    ops: u64,
    recovered_at: Option<Time>,
}

struct FlowLive {
    series: FlowSeries,
    total_bytes: u64,
    total_ops: u64,
    total_drops: u64,
    slo: Slo,
    arrived_at: Time,
}

/// Construction parameters for [`ObsPlane`].
#[derive(Debug, Clone, Copy)]
pub struct ObsConfig {
    /// Control-tick period (ps) — the sampling clock.
    pub control_period: Time,
    /// Run duration (ps), used to size rings no larger than needed.
    pub duration: Time,
    /// Maximum samples retained per series (0 disables series sampling;
    /// counters, histograms and era accounting still run).
    pub retention: usize,
    /// Sample every Nth control tick (≥ 1).
    pub sample_every: u64,
}

/// The live metrics plane owned by the simulation `World`.
pub struct ObsPlane {
    control_period: Time,
    sample_every: u64,
    sampling: bool,
    fault_window: Option<(Time, Time)>,
    flows: Vec<FlowLive>,
    eras: Vec<EraTrack>,
    recovery: Vec<RecoveryTrack>,
    tenants: Vec<TenantObs>,
    engines: Vec<EngineObs>,
}

impl ObsPlane {
    /// Build the plane for `flow_homes[i] = (vm, engine)` per flow.
    /// Fault-era tracking is allocated only when a fault window exists —
    /// healthy runs pay no per-flow histogram memory.
    pub fn new(
        cfg: ObsConfig,
        flow_homes: &[(usize, usize)],
        n_tenants: usize,
        n_engines: usize,
        fault_window: Option<(Time, Time)>,
    ) -> Self {
        let sample_every = cfg.sample_every.max(1);
        let period = cfg.control_period.max(1) * sample_every;
        let expected = (cfg.duration / period) as usize + 2;
        let cap = cfg.retention.min(expected).max(1);
        let sampling = cfg.retention > 0;
        let ring_cap = if sampling { cap } else { 1 };
        let flows = flow_homes
            .iter()
            .enumerate()
            .map(|(i, &(vm, engine))| FlowLive {
                series: FlowSeries::new(i, vm, engine, ring_cap),
                total_bytes: 0,
                total_ops: 0,
                total_drops: 0,
                slo: Slo::BestEffort,
                arrived_at: 0,
            })
            .collect();
        let eras = if fault_window.is_some() {
            (0..flow_homes.len()).map(|_| EraTrack::new()).collect()
        } else {
            Vec::new()
        };
        let recovery = if fault_window.is_some() {
            vec![RecoveryTrack::default(); flow_homes.len()]
        } else {
            Vec::new()
        };
        ObsPlane {
            control_period: cfg.control_period.max(1),
            sample_every,
            sampling,
            fault_window,
            flows,
            eras,
            recovery,
            tenants: (0..n_tenants)
                .map(|vm| TenantObs {
                    vm,
                    bytes: 0,
                    ops: 0,
                    lat: Histogram::new(),
                    bytes_series: SeriesRing::new(ring_cap),
                    ops_series: SeriesRing::new(ring_cap),
                })
                .collect(),
            engines: (0..n_engines)
                .map(|engine| EngineObs {
                    engine,
                    bytes: 0,
                    ops: 0,
                    lat: Histogram::new(),
                    bytes_series: SeriesRing::new(ring_cap),
                })
                .collect(),
        }
    }

    /// Record the SLO a flow is currently held to (at registration and
    /// again after a successful renegotiation). Recovery and window
    /// attainment judge against this.
    pub fn set_flow_slo(&mut self, flow: usize, slo: Slo) {
        self.flows[flow].slo = slo;
    }

    /// Record when a flow was (re-)admitted; post-fault recovery windows
    /// never start before this.
    pub fn note_arrival(&mut self, flow: usize, at: Time) {
        self.flows[flow].arrived_at = at;
    }

    /// Fold one post-warmup completion into every level of the plane.
    /// `at` values are monotone (completions are processed in event
    /// order), which era tracking relies on. Never allocates.
    pub fn on_complete(&mut self, flow: usize, at: Time, lat: u64, bytes: u64) {
        let (tb, to) = {
            let f = &self.flows[flow];
            (f.total_bytes, f.total_ops)
        };
        if let Some((fs, fe)) = self.fault_window {
            let era = if at < fs {
                0
            } else if at < fe {
                1
            } else {
                2
            };
            let tr = &mut self.eras[flow];
            tr.advance_to(era, tb, to);
            tr.lat.record(era, lat);
            if era == 2 {
                self.track_recovery(flow, at, bytes, fe);
            }
        }
        let f = &mut self.flows[flow];
        f.total_bytes += bytes;
        f.total_ops += 1;
        let t = &mut self.tenants[f.vm];
        t.bytes += bytes;
        t.ops += 1;
        t.lat.record(lat);
        let e = &mut self.engines[f.engine];
        e.bytes += bytes;
        e.ops += 1;
        e.lat.record(lat);
    }

    /// Count a dropped message (mirrors `FlowMetrics::on_drop` call sites).
    pub fn on_drop(&mut self, flow: usize) {
        self.flows[flow].total_drops += 1;
    }

    fn track_recovery(&mut self, flow: usize, at: Time, bytes: u64, fault_end: Time) {
        let Some((rate, mode)) = self.flows[flow].slo.required_rate() else {
            return;
        };
        let arrived_at = self.flows[flow].arrived_at;
        let r = &mut self.recovery[flow];
        if r.recovered_at.is_some() {
            return;
        }
        if r.win_start == 0 {
            r.win_start = fault_end.max(arrived_at);
        }
        let period = self.control_period;
        while at >= r.win_start + period {
            let achieved = match mode {
                ShapeMode::Gbps => r.bytes as f64 * SECONDS as f64 / period as f64,
                ShapeMode::Iops => r.ops as f64 * SECONDS as f64 / period as f64,
            };
            if achieved >= rate * RECOVERY_FRACTION {
                r.recovered_at = Some(r.win_start + period);
                return;
            }
            r.win_start += period;
            r.bytes = 0;
            r.ops = 0;
        }
        r.bytes += bytes;
        r.ops += 1;
    }

    /// Sample one flow's signals at a control tick. Called from the
    /// existing `ControlTick` handler with the measurement window it
    /// already computed for the control plane — the plane adds no events
    /// and re-measures nothing. Never allocates.
    #[allow(clippy::too_many_arguments)]
    pub fn on_control_sample(
        &mut self,
        tick: u64,
        flow: usize,
        span: Time,
        window_bytes: u64,
        window_ops: u64,
        window_p99: Option<u64>,
        queue_depth: usize,
        directives: u64,
    ) {
        if !self.sampling || tick % self.sample_every != 0 {
            return;
        }
        let idx = tick / self.sample_every;
        let att = window_attainment_ppm(
            &self.flows[flow].slo,
            span,
            window_bytes,
            window_ops,
            window_p99,
        );
        let f = &mut self.flows[flow];
        f.series.bytes.push_at(idx, f.total_bytes);
        f.series.ops.push_at(idx, f.total_ops);
        f.series.dropped.push_at(idx, f.total_drops);
        f.series.queue_depth.push_at(idx, queue_depth as u64);
        f.series.attainment_ppm.push_at(idx, att);
        f.series.p99_ps.push_at(idx, window_p99.unwrap_or(GAUGE_NONE));
        f.series.directives.push_at(idx, directives);
    }

    /// Close a control tick: push the tenant/engine rollup series.
    pub fn on_tick_done(&mut self, tick: u64) {
        if !self.sampling || tick % self.sample_every != 0 {
            return;
        }
        let idx = tick / self.sample_every;
        for t in &mut self.tenants {
            t.bytes_series.push_at(idx, t.bytes);
            t.ops_series.push_at(idx, t.ops);
        }
        for e in &mut self.engines {
            e.bytes_series.push_at(idx, e.bytes);
        }
    }

    /// Per-era (bytes, ops, p99) for a flow, derived from the series-plane
    /// counters. Only meaningful on faulted runs.
    pub fn flow_eras(&self, flow: usize) -> Option<[(u64, u64, u64); 3]> {
        let tr = self.eras.get(flow)?;
        let f = &self.flows[flow];
        let eras = tr.eras(f.total_bytes, f.total_ops);
        let mut out = [(0, 0, 0); 3];
        for (k, &(b, o)) in eras.iter().enumerate() {
            out[k] = (b, o, tr.lat.window(k).percentile(99.0));
        }
        Some(out)
    }

    /// When the flow's first compliant post-fault window closed, if it did.
    pub fn recovered_at(&self, flow: usize) -> Option<Time> {
        self.recovery.get(flow).and_then(|r| r.recovered_at)
    }

    /// Read-only access to one flow's series bundle (None when the flow id
    /// is out of range). This is the accessor behind
    /// [`crate::api::ObsView`]: control planes read telemetry through it
    /// without gaining structural access to the plane.
    pub fn flow_series(&self, flow: usize) -> Option<&FlowSeries> {
        self.flows.get(flow).map(|f| &f.series)
    }

    /// Read-only access to one tenant's rollup (None when out of range).
    pub fn tenant(&self, vm: usize) -> Option<&TenantObs> {
        self.tenants.get(vm)
    }

    /// Read-only access to one engine's rollup (None when out of range).
    pub fn engine(&self, engine: usize) -> Option<&EngineObs> {
        self.engines.get(engine)
    }

    /// Freeze the plane into its end-of-run snapshot.
    pub fn into_snapshot(self) -> ObsSnapshot {
        ObsSnapshot {
            control_period: self.control_period,
            sample_every: self.sample_every,
            flows: self.flows.into_iter().map(|f| f.series).collect(),
            tenants: self.tenants,
            engines: self.engines,
        }
    }
}

/// Attainment of one measurement window against an SLO, in ppm.
/// Mirrors `EraReport::new`'s attainment arithmetic (ratio of achieved to
/// target), quantized to ppm so the digest stays integer-only.
fn window_attainment_ppm(
    slo: &Slo,
    span: Time,
    bytes: u64,
    ops: u64,
    p99: Option<u64>,
) -> u64 {
    if span == 0 {
        return GAUGE_NONE;
    }
    let ratio = match *slo {
        Slo::Throughput { target, .. } => {
            let bps = target.as_bits_per_sec();
            if bps <= 0.0 {
                return GAUGE_NONE;
            }
            (bytes as f64 * 8.0 * SECONDS as f64 / span as f64) / bps
        }
        Slo::Iops { target, .. } => {
            if target <= 0.0 {
                return GAUGE_NONE;
            }
            (ops as f64 * SECONDS as f64 / span as f64) / target
        }
        Slo::Latency { max_ps, .. } => match p99 {
            Some(p) => max_ps as f64 / p.max(1) as f64,
            None => return GAUGE_NONE,
        },
        Slo::BestEffort => return GAUGE_NONE,
    };
    (ratio * 1_000_000.0).min(1e15) as u64
}

/// Immutable end-of-run snapshot of the plane, carried on `SystemReport`.
/// Its [`digest`](ObsSnapshot::digest) is part of the canonical report and
/// asserted byte-identical across event-queue disciplines.
#[derive(Debug, Clone, Default)]
pub struct ObsSnapshot {
    /// Sampling clock (ps per control tick).
    pub control_period: Time,
    /// Every Nth tick sampled.
    pub sample_every: u64,
    /// Per-flow series.
    pub flows: Vec<FlowSeries>,
    /// Tenant rollups.
    pub tenants: Vec<TenantObs>,
    /// Engine rollups.
    pub engines: Vec<EngineObs>,
}

impl ObsSnapshot {
    /// FNV-1a over every series sample, rollup counter, and histogram
    /// bucket in a fixed order. Two snapshots digest equal iff the whole
    /// observable surface matched sample-for-sample.
    pub fn digest(&self) -> u64 {
        let mut h = Fnv64::new();
        h.write_u64(self.control_period);
        h.write_u64(self.sample_every);
        h.write_u64(self.flows.len() as u64);
        for f in &self.flows {
            h.write_u64(f.vm as u64);
            h.write_u64(f.engine as u64);
            for ring in f.signals() {
                fold_ring(&mut h, ring);
            }
        }
        for t in &self.tenants {
            h.write_u64(t.bytes);
            h.write_u64(t.ops);
            fold_hist(&mut h, &t.lat);
            fold_ring(&mut h, &t.bytes_series);
            fold_ring(&mut h, &t.ops_series);
        }
        for e in &self.engines {
            h.write_u64(e.bytes);
            h.write_u64(e.ops);
            fold_hist(&mut h, &e.lat);
            fold_ring(&mut h, &e.bytes_series);
        }
        h.finish()
    }
}

fn fold_ring(h: &mut Fnv64, r: &SeriesRing) {
    h.write_u64(r.len() as u64);
    if !r.is_empty() {
        h.write_u64(r.first_tick());
    }
    for (_, v) in r.iter() {
        h.write_u64(v);
    }
}

fn fold_hist(h: &mut Fnv64, hist: &Histogram) {
    h.write_u64(hist.count());
    for (value, count) in hist.iter() {
        h.write_u64(value);
        h.write_u64(count);
    }
}

/// Minimal 64-bit FNV-1a hasher (the vendored hash crates are offline
/// shims, so the digest is hand-rolled and self-contained).
pub struct Fnv64(u64);

impl Fnv64 {
    /// FNV-1a offset basis.
    pub fn new() -> Self {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }

    /// Fold eight little-endian bytes into the state.
    pub fn write_u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    /// Final hash value.
    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Fnv64::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::MICROS;

    fn plane(fault: Option<(Time, Time)>) -> ObsPlane {
        ObsPlane::new(
            ObsConfig {
                control_period: 100 * MICROS,
                duration: 2_000 * MICROS,
                retention: 64,
                sample_every: 1,
            },
            &[(0, 0), (1, 0)],
            2,
            1,
            fault,
        )
    }

    #[test]
    fn completions_roll_up_tenant_and_engine() {
        let mut p = plane(None);
        p.on_complete(0, 10, 500, 4096);
        p.on_complete(1, 20, 700, 1024);
        p.on_complete(0, 30, 900, 4096);
        let s = p.into_snapshot();
        assert_eq!(s.tenants[0].bytes, 8192);
        assert_eq!(s.tenants[0].ops, 2);
        assert_eq!(s.tenants[1].bytes, 1024);
        assert_eq!(s.engines[0].bytes, 9216);
        assert_eq!(s.engines[0].ops, 3);
        assert_eq!(s.engines[0].lat.count(), 3);
    }

    #[test]
    fn era_boundaries_snapshot_cumulative_counters() {
        let fs = 1000;
        let fe = 2000;
        let mut p = plane(Some((fs, fe)));
        p.on_complete(0, 100, 10, 100); // era 0
        p.on_complete(0, 200, 10, 100); // era 0
        p.on_complete(0, 1500, 10, 50); // era 1
        p.on_complete(0, 2500, 10, 25); // era 2
        p.on_complete(0, 2600, 10, 25); // era 2
        let eras = p.flow_eras(0).unwrap();
        assert_eq!((eras[0].0, eras[0].1), (200, 2));
        assert_eq!((eras[1].0, eras[1].1), (50, 1));
        assert_eq!((eras[2].0, eras[2].1), (50, 2));
    }

    #[test]
    fn skipped_era_stays_empty() {
        let mut p = plane(Some((1000, 2000)));
        p.on_complete(0, 100, 10, 100); // era 0
        p.on_complete(0, 2500, 10, 30); // straight to era 2
        let eras = p.flow_eras(0).unwrap();
        assert_eq!((eras[0].0, eras[0].1), (100, 1));
        assert_eq!((eras[1].0, eras[1].1), (0, 0));
        assert_eq!((eras[2].0, eras[2].1), (30, 1));
    }

    #[test]
    fn recovery_requires_one_full_compliant_window() {
        let period = 100 * MICROS;
        let fe = 1000 * MICROS;
        let mut p = plane(Some((500 * MICROS, fe)));
        // 10 Gbps SLO → 1.25e9 bytes/sec → 125_000 bytes per 100 µs window.
        p.set_flow_slo(0, Slo::gbps(10.0));
        // First window after fault end: far under rate (one 1 KiB op).
        p.on_complete(0, fe + 10 * MICROS, 10, 1024);
        // Completions filling the second window above 95% of rate.
        let win2 = fe + period;
        for k in 0..4u64 {
            p.on_complete(0, win2 + (k + 1) * 10 * MICROS, 10, 32_000);
        }
        // A later completion closes the second window and judges it.
        p.on_complete(0, win2 + period + MICROS, 10, 1024);
        let rec = p.recovered_at(0).expect("second window should comply");
        assert_eq!(rec, win2 + period);
    }

    #[test]
    fn digest_is_deterministic_and_sensitive() {
        let build = |extra: bool| {
            let mut p = plane(None);
            p.on_complete(0, 10, 500, 4096);
            p.on_control_sample(5, 0, 100, 4096, 1, Some(500), 3, 0);
            p.on_tick_done(5);
            if extra {
                p.on_complete(1, 20, 900, 64);
            }
            p.into_snapshot().digest()
        };
        assert_eq!(build(false), build(false));
        assert_ne!(build(false), build(true));
    }

    #[test]
    fn retention_zero_disables_series_but_not_counters() {
        let mut p = ObsPlane::new(
            ObsConfig {
                control_period: 100 * MICROS,
                duration: 1_000 * MICROS,
                retention: 0,
                sample_every: 1,
            },
            &[(0, 0)],
            1,
            1,
            None,
        );
        p.on_control_sample(3, 0, 100, 10, 1, None, 0, 0);
        p.on_tick_done(3);
        p.on_complete(0, 10, 500, 4096);
        let s = p.into_snapshot();
        assert!(s.flows[0].bytes.is_empty());
        assert_eq!(s.tenants[0].bytes, 4096);
    }

    #[test]
    fn sample_every_decimates_ticks() {
        let mut p = ObsPlane::new(
            ObsConfig {
                control_period: 100 * MICROS,
                duration: 10_000 * MICROS,
                retention: 64,
                sample_every: 4,
            },
            &[(0, 0)],
            1,
            1,
            None,
        );
        for tick in 0..12 {
            p.on_control_sample(tick, 0, 100, tick, 1, None, 0, 0);
            p.on_tick_done(tick);
        }
        let s = p.into_snapshot();
        // Ticks 0, 4, 8 sampled → ring indices 0, 1, 2.
        assert_eq!(s.flows[0].bytes.len(), 3);
        assert_eq!(s.flows[0].bytes.get(1), Some(4));
        assert_eq!(s.flows[0].bytes.get(2), Some(8));
    }
}
