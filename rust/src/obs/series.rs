//! Fixed-capacity, power-of-two ring buffer for tick-indexed counter and
//! gauge series.
//!
//! A [`SeriesRing`] stores the last `capacity` samples of a metric, indexed
//! by the **simulation control tick** that produced them — never wall
//! clock. Ticks are monotone; pushing tick `t` after tick `t - k` (a gap
//! left by e.g. a control-plane outage suppressing ticks) carry-fills the
//! missing slots with the previous value, so `get(tick)` stays exact for
//! every retained tick even across wrap-around. This is what makes the
//! series safe to fold into the deterministic report digest: the content
//! is a pure function of the simulation schedule.

/// Ring-buffered `u64` series indexed by monotone sim tick.
///
/// Capacity is rounded up to a power of two so slot lookup is a mask, not
/// a division. Once more than `capacity` ticks have been pushed the oldest
/// samples are overwritten; `first_tick()`/`next_tick()` always bound the
/// retained window exactly.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SeriesRing {
    data: Vec<u64>,
    mask: u64,
    /// Tick index the next push lands on; retained window is
    /// `[next_tick - len, next_tick)`.
    next_tick: u64,
    len: usize,
}

impl SeriesRing {
    /// Create a ring retaining at least `capacity` samples (rounded up to
    /// the next power of two, minimum 1).
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(1).next_power_of_two();
        SeriesRing {
            data: vec![0; cap],
            mask: cap as u64 - 1,
            next_tick: 0,
            len: 0,
        }
    }

    /// Rebuild a ring from a contiguous run of samples starting at
    /// `first_tick` (used by the binary dump reader).
    pub fn from_samples(first_tick: u64, samples: &[u64]) -> Self {
        let mut r = SeriesRing::new(samples.len().max(1));
        for (i, &v) in samples.iter().enumerate() {
            r.push_at(first_tick + i as u64, v);
        }
        r
    }

    #[inline]
    fn slot(&self, tick: u64) -> usize {
        (tick & self.mask) as usize
    }

    /// Record `value` at `tick`. Ticks must be monotone non-decreasing;
    /// skipped ticks are carry-filled with the previous value so the tick
    /// indexing stays dense and exact. Never allocates.
    pub fn push_at(&mut self, tick: u64, value: u64) {
        if self.len == 0 {
            self.next_tick = tick;
        }
        debug_assert!(tick >= self.next_tick, "series ticks must be monotone");
        if tick < self.next_tick {
            return; // defensive: drop out-of-order pushes in release builds
        }
        let carry = if self.len == 0 {
            value
        } else {
            self.data[self.slot(self.next_tick - 1)]
        };
        let gap = tick - self.next_tick;
        if gap >= self.data.len() as u64 {
            // The whole retained window would be carry-filled: do it in one
            // pass and jump the cursor instead of looping per tick.
            for s in self.data.iter_mut() {
                *s = carry;
            }
            self.len = self.data.len();
            self.next_tick = tick;
        } else {
            while self.next_tick < tick {
                let s = self.slot(self.next_tick);
                self.data[s] = carry;
                self.next_tick += 1;
                self.len = (self.len + 1).min(self.data.len());
            }
        }
        let s = self.slot(tick);
        self.data[s] = value;
        self.next_tick = tick + 1;
        self.len = (self.len + 1).min(self.data.len());
    }

    /// Number of retained samples (≤ capacity).
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if nothing has been pushed yet.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Allocated slot count (power of two).
    pub fn capacity(&self) -> usize {
        self.data.len()
    }

    /// Oldest retained tick (meaningless when empty).
    pub fn first_tick(&self) -> u64 {
        self.next_tick - self.len as u64
    }

    /// One past the newest retained tick.
    pub fn next_tick(&self) -> u64 {
        self.next_tick
    }

    /// Value at `tick`, or `None` if that tick is outside the retained
    /// window.
    pub fn get(&self, tick: u64) -> Option<u64> {
        if self.len > 0 && tick >= self.first_tick() && tick < self.next_tick {
            Some(self.data[self.slot(tick)])
        } else {
            None
        }
    }

    /// Newest sample, if any.
    pub fn latest(&self) -> Option<u64> {
        if self.len == 0 {
            None
        } else {
            Some(self.data[self.slot(self.next_tick - 1)])
        }
    }

    /// Iterate `(tick, value)` over the retained window, oldest first.
    pub fn iter(&self) -> impl Iterator<Item = (u64, u64)> + '_ {
        (self.first_tick()..self.next_tick).map(move |t| (t, self.data[self.slot(t)]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_get_without_wrap() {
        let mut r = SeriesRing::new(8);
        for t in 0..5 {
            r.push_at(t, t * 10);
        }
        assert_eq!(r.len(), 5);
        assert_eq!(r.first_tick(), 0);
        assert_eq!(r.next_tick(), 5);
        for t in 0..5 {
            assert_eq!(r.get(t), Some(t * 10));
        }
        assert_eq!(r.get(5), None);
        assert_eq!(r.latest(), Some(40));
    }

    #[test]
    fn capacity_rounds_to_power_of_two() {
        assert_eq!(SeriesRing::new(0).capacity(), 1);
        assert_eq!(SeriesRing::new(5).capacity(), 8);
        assert_eq!(SeriesRing::new(8).capacity(), 8);
        assert_eq!(SeriesRing::new(9).capacity(), 16);
    }

    #[test]
    fn wrap_around_keeps_tick_indexing_exact() {
        let mut r = SeriesRing::new(4);
        for t in 0..11 {
            r.push_at(t, 100 + t);
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.first_tick(), 7);
        assert_eq!(r.next_tick(), 11);
        for t in 0..7 {
            assert_eq!(r.get(t), None, "tick {t} should be evicted");
        }
        for t in 7..11 {
            assert_eq!(r.get(t), Some(100 + t));
        }
    }

    #[test]
    fn gaps_carry_forward_previous_value() {
        let mut r = SeriesRing::new(8);
        r.push_at(0, 7);
        r.push_at(4, 9); // ticks 1..4 missed (e.g. control outage)
        assert_eq!(r.get(1), Some(7));
        assert_eq!(r.get(2), Some(7));
        assert_eq!(r.get(3), Some(7));
        assert_eq!(r.get(4), Some(9));
        assert_eq!(r.len(), 5);
    }

    #[test]
    fn gap_larger_than_capacity_fast_fills() {
        let mut r = SeriesRing::new(4);
        r.push_at(0, 3);
        r.push_at(100, 5);
        assert_eq!(r.len(), 4);
        assert_eq!(r.first_tick(), 97);
        assert_eq!(r.get(97), Some(3));
        assert_eq!(r.get(99), Some(3));
        assert_eq!(r.get(100), Some(5));
        assert_eq!(r.get(96), None);
    }

    #[test]
    fn late_start_anchors_at_first_tick() {
        let mut r = SeriesRing::new(8);
        r.push_at(42, 1);
        assert_eq!(r.first_tick(), 42);
        assert_eq!(r.get(41), None);
        assert_eq!(r.get(42), Some(1));
    }

    #[test]
    fn from_samples_round_trips_iter() {
        let mut r = SeriesRing::new(8);
        for t in 3..9 {
            r.push_at(t, t * t);
        }
        let samples: Vec<u64> = r.iter().map(|(_, v)| v).collect();
        let rebuilt = SeriesRing::from_samples(r.first_tick(), &samples);
        assert_eq!(rebuilt.first_tick(), r.first_tick());
        assert_eq!(rebuilt.next_tick(), r.next_tick());
        assert!(rebuilt.iter().eq(r.iter()));
    }
}
