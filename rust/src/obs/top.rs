//! `arcus top` — terminal tables of the worst flows and tenants by SLO
//! attainment and p99 over the sampled window of a series dump.

use crate::util::units::{Time, MICROS, SECONDS};

use super::dump::DumpData;
use super::plane::GAUGE_NONE;
use super::series::SeriesRing;

/// One flow's digest over its retained sample window.
struct FlowRow {
    flow: usize,
    vm: usize,
    engine: usize,
    /// Average goodput over the window (Gbit/s), if ≥ 2 samples.
    gbps: Option<f64>,
    /// Worst window attainment seen (ratio), if any window had one.
    att_min: Option<f64>,
    /// Latest window attainment.
    att_last: Option<f64>,
    /// Worst window p99 (ps).
    p99_max: Option<u64>,
    /// Latest queue depth sample.
    depth: u64,
    /// Drops over the window.
    drops: u64,
}

fn delta(r: &SeriesRing) -> u64 {
    match (r.get(r.first_tick()), r.latest()) {
        (Some(a), Some(b)) => b.saturating_sub(a),
        _ => 0,
    }
}

fn gauge_min(r: &SeriesRing) -> Option<u64> {
    r.iter().map(|(_, v)| v).filter(|&v| v != GAUGE_NONE).min()
}

fn gauge_max(r: &SeriesRing) -> Option<u64> {
    r.iter().map(|(_, v)| v).filter(|&v| v != GAUGE_NONE).max()
}

fn row(data: &DumpData, i: usize) -> FlowRow {
    let f = &data.flows[i];
    let ticks = f.bytes.len() as u64;
    let span: Time = ticks.saturating_sub(1) * data.control_period * data.sample_every;
    let gbps = if span > 0 {
        Some(delta(&f.bytes) as f64 * 8.0 * SECONDS as f64 / span as f64 / 1e9)
    } else {
        None
    };
    FlowRow {
        flow: f.flow,
        vm: f.vm,
        engine: f.engine,
        gbps,
        att_min: gauge_min(&f.attainment_ppm).map(|v| v as f64 / 1e6),
        att_last: f
            .attainment_ppm
            .latest()
            .filter(|&v| v != GAUGE_NONE)
            .map(|v| v as f64 / 1e6),
        p99_max: gauge_max(&f.p99_ps),
        depth: f.queue_depth.latest().unwrap_or(0),
        drops: delta(&f.dropped),
    }
}

fn fmt_opt(v: Option<f64>) -> String {
    v.map(|x| format!("{x:.3}")).unwrap_or_else(|| "-".into())
}

fn fmt_us(v: Option<u64>) -> String {
    v.map(|x| format!("{:.2}", x as f64 / MICROS as f64))
        .unwrap_or_else(|| "-".into())
}

/// Sort key: worst attainment first (flows with no attainment sort after
/// any measured one), ties broken by worst p99, then id for stability.
fn badness(r: &FlowRow) -> (u64, u64, usize) {
    let att = r
        .att_min
        .map(|a| (a * 1e6).min(1e15) as u64)
        .unwrap_or(u64::MAX);
    (att, u64::MAX - r.p99_max.unwrap_or(0), r.flow)
}

/// Render the worst-flows and worst-tenants tables.
pub fn render_top(data: &DumpData, limit: usize) -> String {
    let mut out = String::new();
    let window_ms = data
        .flows
        .iter()
        .map(|f| f.bytes.len())
        .max()
        .unwrap_or(0) as f64
        * (data.control_period * data.sample_every) as f64
        / 1e9;
    out.push_str(&format!(
        "{} flows, sample window ≤ {:.2} ms ({} ticks/sample)\n\n",
        data.flows.len(),
        window_ms,
        data.sample_every
    ));

    let mut rows: Vec<FlowRow> = (0..data.flows.len()).map(|i| row(data, i)).collect();
    rows.sort_by_key(badness);

    out.push_str("worst flows by attainment / p99:\n");
    out.push_str("flow  vm eng   gbps  att.min att.last  p99.max(us)  depth  drops\n");
    for r in rows.iter().take(limit) {
        out.push_str(&format!(
            "{:>4} {:>3} {:>3} {:>6} {:>8} {:>8} {:>12} {:>6} {:>6}\n",
            r.flow,
            r.vm,
            r.engine,
            r.gbps
                .map(|g| format!("{g:.2}"))
                .unwrap_or_else(|| "-".into()),
            fmt_opt(r.att_min),
            fmt_opt(r.att_last),
            fmt_us(r.p99_max),
            r.depth,
            r.drops,
        ));
    }

    // Tenant rollup: worst attainment / p99 of any member flow, summed rate.
    let n_vms = rows.iter().map(|r| r.vm + 1).max().unwrap_or(0);
    let mut tenants: Vec<(usize, Option<f64>, Option<f64>, Option<u64>, u64)> =
        (0..n_vms).map(|vm| (vm, None, None, None, 0)).collect();
    let mut seen = vec![false; n_vms];
    for r in &rows {
        let t = &mut tenants[r.vm];
        seen[r.vm] = true;
        t.1 = match (t.1, r.gbps) {
            (Some(a), Some(b)) => Some(a + b),
            (a, b) => a.or(b),
        };
        t.2 = match (t.2, r.att_min) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        t.3 = t.3.max(r.p99_max);
        t.4 += r.drops;
    }
    let mut tenants: Vec<_> = tenants
        .into_iter()
        .enumerate()
        .filter(|(i, _)| seen[*i])
        .map(|(_, t)| t)
        .collect();
    tenants.sort_by_key(|t| {
        (
            t.2.map(|a| (a * 1e6).min(1e15) as u64).unwrap_or(u64::MAX),
            u64::MAX - t.3.unwrap_or(0),
            t.0,
        )
    });

    out.push_str("\nworst tenants:\n");
    out.push_str("  vm   gbps  att.min  p99.max(us)  drops\n");
    for (vm, gbps, att, p99, drops) in tenants.iter().take(limit) {
        out.push_str(&format!(
            "{:>4} {:>6} {:>8} {:>12} {:>6}\n",
            vm,
            gbps.map(|g| format!("{g:.2}")).unwrap_or_else(|| "-".into()),
            fmt_opt(*att),
            fmt_us(*p99),
            drops,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::plane::FlowSeries;

    fn mk_flow(flow: usize, vm: usize, att: &[u64], p99: &[u64]) -> FlowSeries {
        let mut f = FlowSeries {
            flow,
            vm,
            engine: 0,
            bytes: SeriesRing::new(8),
            ops: SeriesRing::new(8),
            dropped: SeriesRing::new(8),
            queue_depth: SeriesRing::new(8),
            attainment_ppm: SeriesRing::new(8),
            p99_ps: SeriesRing::new(8),
            directives: SeriesRing::new(8),
        };
        for (t, (&a, &p)) in att.iter().zip(p99).enumerate() {
            let t = t as u64;
            f.bytes.push_at(t, (t + 1) * 125_000);
            f.attainment_ppm.push_at(t, a);
            f.p99_ps.push_at(t, p);
            f.queue_depth.push_at(t, 2);
            f.dropped.push_at(t, t);
        }
        f
    }

    #[test]
    fn worst_flow_sorts_first() {
        let data = DumpData {
            control_period: 100_000_000, // 100 µs
            sample_every: 1,
            flows: vec![
                mk_flow(0, 0, &[990_000, 980_000], &[1_000_000, 2_000_000]),
                mk_flow(1, 1, &[500_000, 700_000], &[9_000_000, 8_000_000]),
            ],
        };
        let out = render_top(&data, 10);
        let flows_at: Vec<usize> = out
            .lines()
            .filter(|l| l.starts_with("   0") || l.starts_with("   1"))
            .map(|l| l.trim().split_whitespace().next().unwrap().parse().unwrap())
            .collect();
        assert_eq!(flows_at[0], 1, "flow 1 (att 0.5) must rank worst:\n{out}");
        assert!(out.contains("0.500"));
        assert!(out.contains("worst tenants"));
    }

    #[test]
    fn handles_empty_dump() {
        let data = DumpData {
            control_period: 1,
            sample_every: 1,
            flows: vec![],
        };
        let out = render_top(&data, 5);
        assert!(out.contains("0 flows"));
    }
}
