//! Wall-clock token bucket: the serving-path analogue of the simulator's
//! cycle-stepped hardware bucket (`shaping::TokenBucket`).
//!
//! The serving runtime shapes real requests in real time; tokens accrue
//! continuously at `rate` units/sec up to `burst`. `try_acquire` either
//! debits and admits, or returns how long to wait — the router uses that
//! hint as its condvar timeout, so shaping costs no busy-waiting.

use std::time::{Duration, Instant};

#[derive(Debug)]
pub struct WallBucket {
    rate: f64,
    burst: f64,
    tokens: f64,
    last: Instant,
}

impl WallBucket {
    /// `rate` in units/sec (bytes or requests); `burst` in units.
    pub fn new(rate: f64, burst: f64) -> Self {
        assert!(rate > 0.0);
        WallBucket { rate, burst: burst.max(1.0), tokens: burst.max(1.0), last: Instant::now() }
    }

    /// Bucket sized for ~10 ms of burst (or 8 units, whichever is larger).
    pub fn for_rate(rate: f64) -> Self {
        Self::new(rate, (rate * 0.01).max(8.0))
    }

    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Reprogram the rate in place (the control plane's reshape); tokens
    /// carry over, clamped to the new burst.
    pub fn set_rate(&mut self, rate: f64) {
        assert!(rate > 0.0);
        self.refill(Instant::now());
        self.rate = rate;
        self.burst = (rate * 0.01).max(8.0);
        self.tokens = self.tokens.min(self.burst);
    }

    fn refill(&mut self, now: Instant) {
        let dt = now.duration_since(self.last).as_secs_f64();
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        self.last = now;
    }

    /// Try to debit `cost` units at `now`; `Err(wait)` = earliest retry.
    pub fn try_acquire_at(&mut self, now: Instant, cost: u64) -> Result<(), Duration> {
        self.refill(now);
        let cost = cost as f64;
        // Oversized requests (cost > burst) drain the full bucket: admit
        // when full, charging what is there (same policy as the hardware
        // model's MTU-greater-than-bucket case).
        let need = cost.min(self.burst);
        if self.tokens >= need {
            self.tokens -= need;
            Ok(())
        } else {
            let deficit = need - self.tokens;
            Err(Duration::from_secs_f64(deficit / self.rate))
        }
    }

    pub fn try_acquire(&mut self, cost: u64) -> Result<(), Duration> {
        self.try_acquire_at(Instant::now(), cost)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_to_rate_in_virtualized_time() {
        // Drive with synthetic Instants so the test is time-independent.
        let t0 = Instant::now();
        let mut b = WallBucket::new(1_000_000.0, 1000.0); // 1M units/s
        let mut now = t0;
        let mut admitted = 0u64;
        // Drain the initial burst then sustain for 100 virtual ms.
        let horizon = t0 + Duration::from_millis(100);
        while now < horizon {
            match b.try_acquire_at(now, 100) {
                Ok(()) => admitted += 100,
                Err(wait) => now += wait,
            }
        }
        // 1000 burst + 100ms × 1M/s = ~101_000 units.
        assert!((100_000..103_000).contains(&admitted), "admitted={admitted}");
    }

    #[test]
    fn undersubscribed_never_waits() {
        let t0 = Instant::now();
        let mut b = WallBucket::new(1_000_000.0, 10_000.0);
        let mut now = t0;
        for _ in 0..100 {
            // 100 units every ms = 100K units/s « 1M.
            assert!(b.try_acquire_at(now, 100).is_ok());
            now += Duration::from_millis(1);
        }
    }

    #[test]
    fn oversized_request_admits_on_full_bucket() {
        let t0 = Instant::now();
        let mut b = WallBucket::new(1000.0, 100.0);
        assert!(b.try_acquire_at(t0, 1_000_000).is_ok()); // > burst, bucket full
        let r = b.try_acquire_at(t0, 1_000_000);
        assert!(r.is_err()); // bucket empty now
    }

    #[test]
    fn set_rate_takes_effect() {
        let t0 = Instant::now();
        let mut b = WallBucket::new(100.0, 8.0);
        b.set_rate(1_000_000.0);
        assert_eq!(b.rate(), 1_000_000.0);
        // High rate: a short wait now refills quickly.
        let mut now = t0;
        let mut admitted = 0;
        let horizon = t0 + Duration::from_millis(10);
        while now < horizon {
            match b.try_acquire_at(now, 100) {
                Ok(()) => admitted += 100,
                Err(w) => now += w,
            }
        }
        assert!(admitted >= 9_000, "admitted={admitted}");
    }
}
