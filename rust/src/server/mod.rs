//! The Arcus serving runtime: a real (wall-clock) server that shapes,
//! batches, and executes accelerator requests through PJRT.
//!
//! This is the paper's architecture on the serving path instead of the
//! simulator: tenants submit requests; a per-tenant **wall-clock token
//! bucket** (provider-programmed, `set_tenant_rate` = the MMIO register
//! write) gates admission; a **dynamic batcher** packs admitted requests of
//! the same work class into grouped executable calls; a single **engine
//! thread** owns the `PjrtRuntime` (PJRT handles are thread-affine) and
//! runs the compiled kernels. Python never runs here.
//!
//! ```text
//! submit() ─→ tenant queues ─(token buckets)─→ batch classes ─→ PJRT engine
//!                    ▲ control plane: set_tenant_rate()            │
//!                    └── responses (per-request channel) ←─────────┘
//! ```

pub mod batcher;
pub mod wallclock;

pub use batcher::WorkKind;
pub use wallclock::WallBucket;

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::metrics::Histogram;
use crate::runtime::{pack_bytes, unpack_bytes, Digest, EncRequest, PjrtRuntime};
use batcher::BatchClass;

/// One tenant's static configuration.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub name: String,
    /// Shaped rate in bytes/sec (None = unshaped / best effort).
    pub rate_bytes_per_sec: Option<f64>,
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub artifacts_dir: PathBuf,
    pub tenants: Vec<TenantSpec>,
    /// Max time a staged request waits for its group to fill.
    pub batch_timeout: Duration,
    /// Per-tenant queue capacity (requests beyond are rejected).
    pub queue_cap: usize,
}

impl ServerConfig {
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Self {
        ServerConfig {
            artifacts_dir: artifacts_dir.into(),
            tenants: Vec::new(),
            batch_timeout: Duration::from_micros(200),
            queue_cap: 4096,
        }
    }

    pub fn tenant(mut self, name: &str, rate_bytes_per_sec: Option<f64>) -> Self {
        self.tenants.push(TenantSpec { name: name.into(), rate_bytes_per_sec });
        self
    }

    pub fn with_queue_cap(mut self, cap: usize) -> Self {
        self.queue_cap = cap;
        self
    }
}

/// A request body.
#[derive(Debug, Clone)]
pub enum Work {
    /// Encrypt + MAC `data` with the tenant's key material.
    EncryptDigest { data: Vec<u8>, key: [u32; 8], nonce: [u32; 3], counter0: u32 },
    /// Checksum `data`.
    Checksum { data: Vec<u8> },
}

impl Work {
    fn kind(&self) -> WorkKind {
        match self {
            Work::EncryptDigest { .. } => WorkKind::EncryptDigest,
            Work::Checksum { .. } => WorkKind::Checksum,
        }
    }
    fn data_len(&self) -> usize {
        match self {
            Work::EncryptDigest { data, .. } | Work::Checksum { data } => data.len(),
        }
    }
}

/// A completed request.
#[derive(Debug)]
pub enum Output {
    Encrypted { cipher: Vec<u8>, tag: Digest },
    Checksum { s1: u32, s2: u32 },
    /// Rejected before execution (queue overflow or shutdown).
    Rejected(&'static str),
}

/// Response with timing breakdown.
#[derive(Debug)]
pub struct Response {
    pub tenant: usize,
    pub output: Output,
    /// submit → response.
    pub latency: Duration,
    /// Bytes of request payload.
    pub bytes: usize,
}

struct Pending {
    work: Work,
    tx: mpsc::Sender<Response>,
    tenant: usize,
    submitted: Instant,
}

#[derive(Default)]
struct Inner {
    queues: Vec<VecDeque<Pending>>,
    /// Pending rate changes: (tenant, bytes/sec or None).
    rate_updates: Vec<(usize, Option<f64>)>,
    shutdown: bool,
}

/// Per-tenant serving statistics.
#[derive(Debug, Clone, Default)]
pub struct TenantStats {
    pub completed: u64,
    pub rejected: u64,
    pub bytes: u64,
    /// Latency histogram in nanoseconds.
    pub latency_ns: Histogram,
    pub first: Option<Instant>,
    pub last: Option<Instant>,
}

impl TenantStats {
    /// Sustained goodput over the active window (bytes/sec).
    pub fn goodput(&self) -> f64 {
        match (self.first, self.last) {
            (Some(a), Some(b)) if b > a => self.bytes as f64 / (b - a).as_secs_f64(),
            _ => 0.0,
        }
    }
}

/// Aggregate server statistics snapshot.
#[derive(Debug, Clone, Default)]
pub struct StatsSnapshot {
    pub tenants: Vec<TenantStats>,
    pub batches: u64,
    pub batched_requests: u64,
}

impl StatsSnapshot {
    /// Mean requests per executable call.
    pub fn mean_group_fill(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches as f64
        }
    }
}

/// The server handle. Dropping it (or calling [`Server::shutdown`]) stops
/// the engine thread.
pub struct Server {
    shared: Arc<(Mutex<Inner>, Condvar)>,
    stats: Arc<Mutex<StatsSnapshot>>,
    inflight: Arc<AtomicU64>,
    worker: Option<std::thread::JoinHandle<()>>,
    n_tenants: usize,
    queue_cap: usize,
}

impl Server {
    /// Start the engine thread (compiles artifacts lazily on it).
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        let n = cfg.tenants.len();
        let shared = Arc::new((
            Mutex::new(Inner {
                queues: (0..n).map(|_| VecDeque::new()).collect(),
                rate_updates: Vec::new(),
                shutdown: false,
            }),
            Condvar::new(),
        ));
        let stats = Arc::new(Mutex::new(StatsSnapshot {
            tenants: vec![TenantStats::default(); n],
            ..Default::default()
        }));
        let inflight = Arc::new(AtomicU64::new(0));

        // Fail fast on a missing manifest before spawning.
        anyhow::ensure!(
            cfg.artifacts_dir.join("manifest.txt").exists(),
            "no artifacts at {} — run `make artifacts`",
            cfg.artifacts_dir.display()
        );

        let queue_cap = cfg.queue_cap;
        let worker = {
            let shared = shared.clone();
            let stats = stats.clone();
            let inflight = inflight.clone();
            std::thread::Builder::new()
                .name("arcus-engine".into())
                .spawn(move || engine_main(cfg, shared, stats, inflight))?
        };
        Ok(Server { shared, stats, inflight, worker: Some(worker), n_tenants: n, queue_cap })
    }

    /// Submit a request; the response arrives on the returned channel.
    pub fn submit(&self, tenant: usize, work: Work) -> mpsc::Receiver<Response> {
        assert!(tenant < self.n_tenants, "unknown tenant {tenant}");
        let (tx, rx) = mpsc::channel();
        let (lock, cv) = &*self.shared;
        let mut inner = lock.lock().unwrap();
        let pending = Pending { work, tx, tenant, submitted: Instant::now() };
        if inner.shutdown {
            respond_rejected(pending, "shutdown");
        } else if inner.queues[tenant].len() >= self.queue_cap {
            respond_rejected(pending, "queue full");
        } else {
            self.inflight.fetch_add(1, Ordering::Relaxed);
            inner.queues[tenant].push_back(pending);
            cv.notify_one();
        }
        rx
    }

    /// Submit and wait.
    pub fn submit_blocking(&self, tenant: usize, work: Work) -> Response {
        self.submit(tenant, work).recv().expect("engine thread died")
    }

    /// Reprogram a tenant's shaping rate (the control plane's register
    /// write; takes effect on the next worker iteration).
    pub fn set_tenant_rate(&self, tenant: usize, rate_bytes_per_sec: Option<f64>) {
        let (lock, cv) = &*self.shared;
        let mut inner = lock.lock().unwrap();
        inner.rate_updates.push((tenant, rate_bytes_per_sec));
        cv.notify_one();
    }

    /// Requests accepted but not yet answered.
    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }

    pub fn stats(&self) -> StatsSnapshot {
        self.stats.lock().unwrap().clone()
    }

    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        {
            let (lock, cv) = &*self.shared;
            lock.lock().unwrap().shutdown = true;
            cv.notify_all();
        }
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn respond_rejected(p: Pending, why: &'static str) {
    let _ = p.tx.send(Response {
        tenant: p.tenant,
        output: Output::Rejected(why),
        latency: p.submitted.elapsed(),
        bytes: 0,
    });
}

/// A request admitted past its tenant's shaper, staged for batching.
struct Ticket {
    pending: Pending,
    payload: Vec<u32>,
}

/// The engine thread: shaping, batching, execution.
fn engine_main(
    cfg: ServerConfig,
    shared: Arc<(Mutex<Inner>, Condvar)>,
    stats: Arc<Mutex<StatsSnapshot>>,
    inflight: Arc<AtomicU64>,
) {
    let rt = match PjrtRuntime::load(&cfg.artifacts_dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("arcus-engine: failed to load artifacts: {e:#}");
            // Drain everything with rejections until shutdown.
            let (lock, _) = &*shared;
            let mut inner = lock.lock().unwrap();
            inner.shutdown = true;
            for q in &mut inner.queues {
                while let Some(p) = q.pop_front() {
                    respond_rejected(p, "artifact load failed");
                }
            }
            return;
        }
    };

    let mut shapers: Vec<Option<WallBucket>> = cfg
        .tenants
        .iter()
        .map(|t| t.rate_bytes_per_sec.map(WallBucket::for_rate))
        .collect();

    // One staging class per (kind, batch size), with capacity = the LARGEST
    // compiled group for that batch; the executable shape is picked at
    // flush time to fit the actual group (a 5-request flush runs on the
    // 8-slot executable, a 100-request burst on the 128-slot one).
    let mut classes: Vec<BatchClass<Ticket>> = Vec::new();
    for kind in [WorkKind::EncryptDigest, WorkKind::Checksum] {
        let mut by_batch: std::collections::HashMap<usize, usize> = Default::default();
        for (group, batch) in rt.manifest().group_shapes(kind.grouped_artifact()) {
            let g = by_batch.entry(batch).or_insert(0);
            *g = (*g).max(group);
        }
        for (batch, group) in by_batch {
            classes.push(BatchClass::new(kind, group, batch));
        }
    }
    classes.sort_by_key(|c| c.batch);

    let mut rr_next = 0usize; // round-robin pointer over tenants
    loop {
        // -- 1. Pull work from tenant queues through the shapers. ---------
        let mut earliest_retry: Option<Duration> = None;
        let mut admitted: Vec<Ticket> = Vec::new();
        let shutdown;
        {
            let (lock, _) = &*shared;
            let mut inner = lock.lock().unwrap();
            shutdown = inner.shutdown;
            for (tenant, rate) in inner.rate_updates.drain(..) {
                shapers[tenant] = rate.map(WallBucket::for_rate);
            }
            let n = inner.queues.len().max(1);
            for i in 0..n {
                let t = (rr_next + i) % n;
                loop {
                    let Some(front) = inner.queues[t].front() else { break };
                    let cost = front.work.data_len() as u64;
                    match shapers[t].as_mut().map(|s| s.try_acquire(cost)) {
                        Some(Err(wait)) => {
                            earliest_retry = Some(match earliest_retry {
                                Some(w) => w.min(wait),
                                None => wait,
                            });
                            break;
                        }
                        _ => {
                            let p = inner.queues[t].pop_front().unwrap();
                            let payload = match &p.work {
                                Work::EncryptDigest { data, .. } | Work::Checksum { data } => {
                                    pack_bytes(data)
                                }
                            };
                            admitted.push(Ticket { pending: p, payload });
                        }
                    }
                }
            }
            rr_next = (rr_next + 1) % n;
        }

        // -- 2. Stage admitted requests into batch classes. ---------------
        let now = Instant::now();
        for ticket in admitted {
            let kind = ticket.pending.work.kind();
            let blocks = ticket.payload.len() / 16;
            let class = classes
                .iter_mut()
                .filter(|c| c.kind == kind)
                .find(|c| c.fits(blocks));
            match class {
                Some(c) => c.stage(ticket, blocks, now),
                None => {
                    // Bigger than every grouped shape: execute singly.
                    execute_single(&rt, ticket, &stats, &inflight);
                }
            }
        }

        // -- 3. Flush ready classes. A partial group also flushes when no
        //       more work is queued (idle flush) — but only after a short
        //       grace period, so a burst mid-submission still coalesces
        //       into full groups while a lone sequential request pays tens
        //       of microseconds instead of the full batch timeout.
        let queues_empty = {
            let (lock, _) = &*shared;
            let inner = lock.lock().unwrap();
            inner.queues.iter().all(|q| q.is_empty())
        };
        let grace = cfg.batch_timeout / 2;
        let now = Instant::now();
        let mut flushed_any = false;
        for c in classes.iter_mut() {
            while c.should_flush(now, cfg.batch_timeout)
                || (queues_empty
                    && c.oldest_age(now).map(|a| a >= grace).unwrap_or(false))
            {
                let group = c.take_group();
                if group.is_empty() {
                    break;
                }
                flushed_any = true;
                let shape = rt
                    .manifest()
                    .pick_group_shape(c.kind.grouped_artifact(), c.batch, group.len())
                    .expect("grouped artifact exists");
                execute_group(&rt, c.kind, shape, group, &stats, &inflight);
            }
        }
        if flushed_any {
            continue; // new capacity may admit more work immediately
        }

        if shutdown {
            // Reject whatever is left and exit.
            let (lock, _) = &*shared;
            let mut inner = lock.lock().unwrap();
            for q in &mut inner.queues {
                while let Some(p) = q.pop_front() {
                    inflight.fetch_sub(1, Ordering::Relaxed);
                    respond_rejected(p, "shutdown");
                }
            }
            for c in classes.iter_mut() {
                for s in c.take_group() {
                    inflight.fetch_sub(1, Ordering::Relaxed);
                    respond_rejected(s.ticket.pending, "shutdown");
                }
            }
            return;
        }

        // -- 4. Sleep until the next deadline (shaper retry or batch
        //       timeout), or a submitter wakes us. -------------------------
        let now = Instant::now();
        let mut wait = earliest_retry.unwrap_or(Duration::from_millis(5));
        let deadline_window = if queues_empty { grace } else { cfg.batch_timeout };
        for c in &classes {
            if let Some(d) = c.flush_deadline(deadline_window) {
                wait = wait.min(d.saturating_duration_since(now));
            }
        }
        let (lock, cv) = &*shared;
        let inner = lock.lock().unwrap();
        if !inner.shutdown && inner.queues.iter().all(|q| q.is_empty()) || !wait.is_zero() {
            let _ = cv
                .wait_timeout(inner, wait.max(Duration::from_micros(10)))
                .unwrap();
        }
    }
}

fn record_response(
    stats: &Arc<Mutex<StatsSnapshot>>,
    inflight: &Arc<AtomicU64>,
    pending: Pending,
    output: Output,
    bytes: usize,
) {
    let now = Instant::now();
    let latency = now.duration_since(pending.submitted);
    {
        let mut s = stats.lock().unwrap();
        let t = &mut s.tenants[pending.tenant];
        match output {
            Output::Rejected(_) => t.rejected += 1,
            _ => {
                t.completed += 1;
                t.bytes += bytes as u64;
                t.latency_ns.record(latency.as_nanos() as u64);
                if t.first.is_none() {
                    t.first = Some(now);
                }
                t.last = Some(now);
            }
        }
    }
    inflight.fetch_sub(1, Ordering::Relaxed);
    let _ = pending.tx.send(Response { tenant: pending.tenant, output, latency, bytes });
}

fn execute_group(
    rt: &PjrtRuntime,
    kind: WorkKind,
    shape: (usize, usize),
    group: Vec<batcher::Staged<Ticket>>,
    stats: &Arc<Mutex<StatsSnapshot>>,
    inflight: &Arc<AtomicU64>,
) {
    {
        let mut s = stats.lock().unwrap();
        s.batches += 1;
        s.batched_requests += group.len() as u64;
    }
    match kind {
        WorkKind::EncryptDigest => {
            let reqs: Vec<EncRequest> = group
                .iter()
                .map(|s| {
                    let Work::EncryptDigest { key, nonce, counter0, .. } =
                        &s.ticket.pending.work
                    else {
                        unreachable!()
                    };
                    EncRequest {
                        payload: s.ticket.payload.clone(),
                        key: *key,
                        nonce: *nonce,
                        counter0: *counter0,
                    }
                })
                .collect();
            match rt.encrypt_digest_group(&reqs, shape) {
                Ok(outs) => {
                    for (staged, (cipher, tag)) in group.into_iter().zip(outs) {
                        let len = staged.ticket.pending.work.data_len();
                        let bytes = unpack_bytes(&cipher, len);
                        record_response(
                            stats,
                            inflight,
                            staged.ticket.pending,
                            Output::Encrypted { cipher: bytes, tag },
                            len,
                        );
                    }
                }
                Err(e) => reject_group(group, stats, inflight, e),
            }
        }
        WorkKind::Checksum => {
            let payloads: Vec<Vec<u32>> =
                group.iter().map(|s| s.ticket.payload.clone()).collect();
            match rt.checksum_group(&payloads, shape) {
                Ok(sums) => {
                    for (staged, (s1, s2)) in group.into_iter().zip(sums) {
                        let len = staged.ticket.pending.work.data_len();
                        record_response(
                            stats,
                            inflight,
                            staged.ticket.pending,
                            Output::Checksum { s1, s2 },
                            len,
                        );
                    }
                }
                Err(e) => reject_group(group, stats, inflight, e),
            }
        }
    }
}

fn reject_group(
    group: Vec<batcher::Staged<Ticket>>,
    stats: &Arc<Mutex<StatsSnapshot>>,
    inflight: &Arc<AtomicU64>,
    e: anyhow::Error,
) {
    eprintln!("arcus-engine: batch failed: {e:#}");
    for staged in group {
        record_response(stats, inflight, staged.ticket.pending, Output::Rejected("exec failed"), 0);
    }
}

fn execute_single(
    rt: &PjrtRuntime,
    ticket: Ticket,
    stats: &Arc<Mutex<StatsSnapshot>>,
    inflight: &Arc<AtomicU64>,
) {
    let len = ticket.pending.work.data_len();
    let out = match &ticket.pending.work {
        Work::EncryptDigest { key, nonce, counter0, .. } => rt
            .encrypt_digest(&ticket.payload, key, nonce, *counter0)
            .map(|(cipher, tag)| Output::Encrypted { cipher: unpack_bytes(&cipher, len), tag }),
        Work::Checksum { .. } => {
            rt.checksum(&ticket.payload).map(|(s1, s2)| Output::Checksum { s1, s2 })
        }
    };
    match out {
        Ok(output) => record_response(stats, inflight, ticket.pending, output, len),
        Err(e) => {
            eprintln!("arcus-engine: request failed: {e:#}");
            record_response(stats, inflight, ticket.pending, Output::Rejected("exec failed"), 0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn artifacts() -> Option<PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.txt").exists() {
            Some(dir)
        } else {
            eprintln!("skipping: run `make artifacts` first");
            None
        }
    }

    #[test]
    fn serve_encrypt_roundtrip_and_checksum() {
        let Some(dir) = artifacts() else { return };
        let server = Server::start(
            ServerConfig::new(dir).tenant("t0", None).tenant("t1", None),
        )
        .unwrap();
        let data = b"arcus serves accelerator requests with slo guarantees".to_vec();
        let key = [5u32; 8];
        let nonce = [1u32, 2, 3];
        let r = server.submit_blocking(
            0,
            Work::EncryptDigest { data: data.clone(), key, nonce, counter0: 7 },
        );
        let Output::Encrypted { cipher, tag } = r.output else {
            panic!("unexpected output {:?}", r.output)
        };
        assert_ne!(cipher, data);
        // Round-trip through the server (counter-mode involution).
        let r2 = server.submit_blocking(
            0,
            Work::EncryptDigest { data: cipher.clone(), key, nonce, counter0: 7 },
        );
        let Output::Encrypted { cipher: back, tag: tag2 } = r2.output else {
            panic!()
        };
        assert_eq!(back, data);
        let _ = (tag, tag2);

        // Checksum matches the native oracle exactly (grouped results are
        // shift-corrected to the request's own length).
        let r3 = server.submit_blocking(1, Work::Checksum { data: data.clone() });
        let Output::Checksum { s1, s2 } = r3.output else { panic!() };
        let words = crate::runtime::pack_bytes(&data);
        assert_eq!((s1, s2), crate::runtime::fletcher_native(&words));
        server.shutdown();
    }

    #[test]
    fn batching_groups_concurrent_requests() {
        let Some(dir) = artifacts() else { return };
        let server = std::sync::Arc::new(
            Server::start(
                ServerConfig::new(dir).tenant("t0", None),
            )
            .unwrap(),
        );
        // Warm up (compile) before the batch burst.
        let _ = server.submit_blocking(0, Work::Checksum { data: vec![1; 512] });
        let rxs: Vec<_> = (0..32)
            .map(|i| server.submit(0, Work::Checksum { data: vec![i as u8; 512] }))
            .collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(matches!(r.output, Output::Checksum { .. }));
        }
        let stats = server.stats();
        assert_eq!(stats.tenants[0].completed, 33);
        assert!(
            stats.mean_group_fill() > 1.5,
            "expected batching, got fill {:.2} over {} batches",
            stats.mean_group_fill(),
            stats.batches
        );
    }

    #[test]
    fn shaping_limits_tenant_throughput() {
        let Some(dir) = artifacts() else { return };
        // Tenant 0 shaped to 2 MB/s, tenant 1 unshaped.
        let server = Server::start(
            ServerConfig::new(dir)
                .tenant("shaped", Some(2_000_000.0))
                .tenant("free", None),
        )
        .unwrap();
        // Warm up the executable cache.
        let _ = server.submit_blocking(0, Work::Checksum { data: vec![0; 1024] });
        let start = Instant::now();
        let rxs: Vec<_> = (0..200)
            .map(|_| server.submit(0, Work::Checksum { data: vec![7; 4096] }))
            .collect();
        for rx in rxs {
            let _ = rx.recv().unwrap();
        }
        let elapsed = start.elapsed().as_secs_f64();
        let rate = 200.0 * 4096.0 / elapsed;
        // 819 KB of work at 2 MB/s ≈ 0.4 s (minus the ~20 KB initial burst).
        assert!(
            rate < 3_000_000.0,
            "shaped tenant ran at {:.2} MB/s",
            rate / 1e6
        );
        server.shutdown();
    }
}
