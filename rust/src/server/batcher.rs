//! Dynamic batcher: stage admitted requests per (work kind, block class)
//! and flush a group when it fills or its oldest member exceeds the batch
//! timeout — the classic serving trade between throughput (bigger groups
//! amortize dispatch) and latency (don't hold a lone request hostage).

use std::time::{Duration, Instant};

use crate::runtime::ArtifactKind;

/// Work classes the server batches (grouped artifacts exist for these).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WorkKind {
    EncryptDigest,
    Checksum,
}

impl WorkKind {
    pub fn grouped_artifact(self) -> ArtifactKind {
        match self {
            WorkKind::EncryptDigest => ArtifactKind::EncryptDigestMany,
            WorkKind::Checksum => ArtifactKind::ChecksumMany,
        }
    }
}

/// A staged request (opaque ticket + the shape-relevant facts).
#[derive(Debug)]
pub struct Staged<T> {
    pub ticket: T,
    pub blocks: usize,
    pub staged_at: Instant,
}

/// One batch class: requests whose padded size fits `batch` blocks.
#[derive(Debug)]
pub struct BatchClass<T> {
    pub kind: WorkKind,
    /// Blocks per request slot.
    pub batch: usize,
    /// Requests per executable call.
    pub group: usize,
    staged: Vec<Staged<T>>,
}

impl<T> BatchClass<T> {
    pub fn new(kind: WorkKind, group: usize, batch: usize) -> Self {
        BatchClass { kind, batch, group, staged: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.staged.len()
    }
    pub fn is_empty(&self) -> bool {
        self.staged.is_empty()
    }

    /// Does a request of `blocks` belong to this class?
    pub fn fits(&self, blocks: usize) -> bool {
        blocks <= self.batch
    }

    pub fn stage(&mut self, ticket: T, blocks: usize, now: Instant) {
        debug_assert!(self.fits(blocks));
        self.staged.push(Staged { ticket, blocks, staged_at: now });
    }

    /// Time the oldest staged request has waited.
    pub fn oldest_age(&self, now: Instant) -> Option<Duration> {
        self.staged.first().map(|s| now.duration_since(s.staged_at))
    }

    /// Flush decision: full group, or timeout expired on the oldest.
    pub fn should_flush(&self, now: Instant, timeout: Duration) -> bool {
        self.staged.len() >= self.group
            || self.oldest_age(now).map(|a| a >= timeout).unwrap_or(false)
    }

    /// Take up to one group's worth of staged requests (FIFO).
    pub fn take_group(&mut self) -> Vec<Staged<T>> {
        let n = self.staged.len().min(self.group);
        self.staged.drain(..n).collect()
    }

    /// Deadline at which the current oldest request must flush.
    pub fn flush_deadline(&self, timeout: Duration) -> Option<Instant> {
        self.staged.first().map(|s| s.staged_at + timeout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flushes_on_full_group() {
        let now = Instant::now();
        let mut c: BatchClass<u32> = BatchClass::new(WorkKind::Checksum, 4, 16);
        for i in 0..3 {
            c.stage(i, 10, now);
            assert!(!c.should_flush(now, Duration::from_millis(1)));
        }
        c.stage(3, 10, now);
        assert!(c.should_flush(now, Duration::from_millis(1)));
        let g = c.take_group();
        assert_eq!(g.len(), 4);
        assert!(c.is_empty());
    }

    #[test]
    fn flushes_on_timeout() {
        let now = Instant::now();
        let mut c: BatchClass<u32> = BatchClass::new(WorkKind::Checksum, 8, 16);
        c.stage(0, 16, now);
        let timeout = Duration::from_micros(200);
        assert!(!c.should_flush(now, timeout));
        assert!(c.should_flush(now + Duration::from_micros(300), timeout));
        assert_eq!(c.flush_deadline(timeout), Some(now + timeout));
    }

    #[test]
    fn take_group_is_fifo_and_partial() {
        let now = Instant::now();
        let mut c: BatchClass<u32> = BatchClass::new(WorkKind::EncryptDigest, 2, 64);
        for i in 0..5 {
            c.stage(i, 1, now);
        }
        assert_eq!(c.take_group().iter().map(|s| s.ticket).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(c.take_group().iter().map(|s| s.ticket).collect::<Vec<_>>(), vec![2, 3]);
        assert_eq!(c.take_group().len(), 1);
        assert!(c.take_group().is_empty());
    }

    #[test]
    fn fits_respects_batch() {
        let c: BatchClass<u32> = BatchClass::new(WorkKind::Checksum, 8, 16);
        assert!(c.fits(16));
        assert!(!c.fits(17));
    }
}
