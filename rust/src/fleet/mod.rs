//! Multi-host fleet: N per-host worlds under one fleet-level control tier
//! with incremental (xDS-style) directive distribution.
//!
//! # Architecture
//!
//! A [`FleetPlane`] shards one [`ExperimentSpec`] template into per-host
//! specs (`host = vm % hosts`, so a tenant's flows never straddle hosts),
//! builds one full [`Engine`] per host — each with its own shaper trees,
//! devices, observability plane, and *local* control plane — and advances
//! all hosts between deterministic interchange barriers at control-tick
//! boundaries. Between barriers hosts share no state, so they may run on
//! separate worker threads; at each barrier the fleet tier runs strictly
//! sequentially, in host order. The event cores therefore execute exactly
//! the same schedule regardless of thread count — the determinism suite
//! pins byte-identical canonical reports for 1 vs N threads (and across
//! all three event-queue disciplines).
//!
//! # Distribution protocol
//!
//! The fleet planner is the Autothrottle-style slow tier above the per-host
//! fast loops: per `(host, tenant)` it publishes `SetAggregate` envelope
//! deltas through a [`DeltaDistributor`] — versioned per stream, delivered
//! after a configurable propagation delay, dropped inside
//! `ControlOutage`-style windows, re-offered every round until the host
//! ACKs the applied version at a later barrier. Hosts apply a batch only
//! when its version exceeds the stream's last applied version, so re-sends
//! are idempotent. Publication → first-successful-delivery staleness is
//! ledgered per batch and surfaces as
//! `SystemReport::directive_staleness_max` (worst case) and per host in
//! `SystemReport::host_rollups` — *next to* the in-host apply lag
//! `directive_lag_max`, which stays pinned at the reconfiguration latency
//! because delivered directives are re-stamped at their delivery time.
//!
//! # Why staleness hurts SLOs
//!
//! Under normal operation the fleet tier *tightens* every tenant envelope
//! to `slo_sum × tight_ceiling` (committed rate plus a small borrow
//! margin). When a tenant's measured attainment drops below the floor —
//! e.g. its accelerator degraded — the planner publishes a *boost*
//! envelope (`slo_sum × boost_ceiling`) so the local plane's per-flow
//! catch-up boosts actually have room to drain the backlog. A delayed or
//! dropped boost delta postpones exactly that: the longer the staleness,
//! the longer post-fault catch-up runs at the tight ceiling, and the worse
//! the fault-era attainment — the scenario a single-world Arcus cannot
//! express.

use std::collections::BTreeMap;

use crate::api::distribution::{DeltaDistributor, DirectiveAck};
use crate::api::{Directive, ObsView};
use crate::faults::{fault_window, FaultKind};
use crate::shaping::ShapeMode;
use crate::sim::{BinaryHeapQueue, EventQueue};
use crate::system::{
    Engine, EngineEvent, ExperimentSpec, HostRollup, Mode, SystemReport,
};
use crate::util::units::Time;

/// Fleet-tier configuration: sharding, interchange cadence, and the
/// distribution protocol's failure model.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of hosts to shard the template across (`vm % hosts`).
    pub hosts: usize,
    /// Worker threads for advancing hosts between barriers. `0` means one
    /// per host; `1` runs hosts serially. Any value produces byte-identical
    /// reports.
    pub threads: usize,
    /// Publish → delivery propagation delay for directive batches.
    pub propagation_delay: Time,
    /// Interchange barriers every N control periods (≥ 1).
    pub interchange_every: u64,
    /// Windows `[start, end)` during which delivery attempts are *lost*
    /// (the batch stays outstanding and is re-offered next round) — the
    /// fleet-level analogue of a `ControlOutage` fault.
    pub drop_windows: Vec<(Time, Time)>,
    /// Normal-operation tenant envelope: `ceiling = slo_sum × tight_ceiling`.
    pub tight_ceiling: f64,
    /// Under-attainment envelope: `ceiling = slo_sum × boost_ceiling`,
    /// giving the local plane's per-flow boosts room to drain backlog.
    pub boost_ceiling: f64,
    /// Publish a boost when any of the tenant's flows samples attainment
    /// below this (parts-per-million).
    pub attainment_floor_ppm: u64,
    /// Consecutive clean barriers required before a boosted tenant drops
    /// back to the tight envelope (flap damping).
    pub clear_rounds: u32,
    /// Re-publish every stream's current envelope every N barriers even
    /// without a level change (periodic xDS refresh; keeps envelopes in
    /// force across local re-announcements and exercises the protocol on
    /// healthy runs). `0` disables refresh.
    pub refresh_every: u64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            hosts: 2,
            threads: 0,
            propagation_delay: 0,
            interchange_every: 1,
            drop_windows: Vec::new(),
            tight_ceiling: 1.05,
            boost_ceiling: 2.0,
            attainment_floor_ppm: 970_000,
            clear_rounds: 3,
            refresh_every: 16,
        }
    }
}

impl FleetConfig {
    /// Validate, with actionable messages (CLI/config surface).
    pub fn validate(&self) -> Result<(), String> {
        if self.hosts == 0 {
            return Err("fleet: hosts must be ≥ 1".into());
        }
        if self.interchange_every == 0 {
            return Err("fleet: interchange_every must be ≥ 1".into());
        }
        if !(self.tight_ceiling > 0.0) || !(self.boost_ceiling > 0.0) {
            return Err("fleet: ceiling factors must be > 0".into());
        }
        if self.boost_ceiling < self.tight_ceiling {
            return Err("fleet: boost_ceiling must be ≥ tight_ceiling".into());
        }
        for &(s, e) in &self.drop_windows {
            if s >= e {
                return Err(format!("fleet: empty drop window [{s}, {e})"));
            }
        }
        Ok(())
    }
}

/// Which host owns tenant `vm` under the fleet partitioning.
pub fn host_of(vm: usize, hosts: usize) -> usize {
    vm % hosts.max(1)
}

/// Build host `h`'s spec from the fleet template: the subset of flows whose
/// tenant lives on `h` (global flow/VM ids preserved — traffic streams are
/// keyed by `(seed, flow id)`, so a flow generates the identical arrival
/// sequence it would in a single-world run), the full device list,
/// remapped lifecycle events, and the host's share of the fault plan
/// (component faults land on host 0; `RogueTenant` follows its flow).
///
/// Returns the spec plus the mapping from local flow position to the
/// template's flow position.
pub fn host_spec(template: &ExperimentSpec, h: usize, hosts: usize) -> (ExperimentSpec, Vec<usize>) {
    let globals: Vec<usize> = template
        .flows
        .iter()
        .enumerate()
        .filter(|(_, f)| host_of(f.vm, hosts) == h)
        .map(|(i, _)| i)
        .collect();
    let local_of = |global: usize| globals.iter().position(|&g| g == global);
    let mut spec = template.clone();
    spec.flows = globals.iter().map(|&g| template.flows[g].clone()).collect();
    spec.lifecycle = template
        .lifecycle
        .iter()
        .filter_map(|e| {
            let local = local_of(e.flow())?;
            let mut e = *e;
            match &mut e {
                crate::system::LifecycleEvent::Arrive { flow, .. }
                | crate::system::LifecycleEvent::Depart { flow, .. }
                | crate::system::LifecycleEvent::Renegotiate { flow, .. } => *flow = local,
            }
            Some(e)
        })
        .collect();
    spec.faults = template
        .faults
        .iter()
        .filter_map(|f| match f.kind {
            FaultKind::RogueTenant { flow } => {
                let vm = template.flows.get(flow)?.vm;
                if host_of(vm, hosts) != h {
                    return None;
                }
                let mut f = f.clone();
                f.kind = FaultKind::RogueTenant { flow: local_of(flow)? };
                Some(f)
            }
            // Component faults (accel/link/SSD/profile/control outage)
            // strike host 0's copy of the hardware.
            _ if h == 0 => Some(f.clone()),
            _ => None,
        })
        .collect();
    // The fleet tier owns the slow envelope loop: host planes run the
    // *static* hierarchical Arcus plane so the in-host AIMD slow tier
    // doesn't fight the distributed one.
    spec.adaptive = None;
    if spec.mode == Mode::Arcus {
        spec.hierarchy = true;
    }
    (spec, globals)
}

/// Committed (SLO-sum) bytes/sec per `(tenant, engine)` on one host spec —
/// the guarantees the fleet envelopes are anchored on. Only byte-rated
/// SLOs participate (IOPS streams keep their local envelopes).
fn tenant_engine_commit(spec: &ExperimentSpec) -> BTreeMap<(usize, usize), f64> {
    let mut out: BTreeMap<(usize, usize), f64> = BTreeMap::new();
    let storage_tree = spec.accels.len();
    for f in &spec.flows {
        if let Some((rate, ShapeMode::Gbps)) = f.slo.required_rate() {
            let engine = if f.kind == crate::flow::FlowKind::Accel { f.accel } else { storage_tree };
            *out.entry((f.vm, engine)).or_insert(0.0) += rate;
        }
    }
    out
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Level {
    Tight,
    Boost,
}

struct HostSlot<Q: EventQueue<EngineEvent>> {
    engine: Engine<Q>,
    /// Local flow position → template flow position.
    globals: Vec<usize>,
    /// Committed bytes/sec per (tenant vm, engine) on this host.
    commit: BTreeMap<(usize, usize), f64>,
}

struct PendingApply {
    host: usize,
    class: usize,
    version: u64,
    apply_at: Time,
}

/// The fleet: per-host engines plus the distribution tier's sender state.
pub struct FleetPlane<Q: EventQueue<EngineEvent> + Default> {
    cfg: FleetConfig,
    template: ExperimentSpec,
    hosts: Vec<HostSlot<Q>>,
    dist: DeltaDistributor,
    /// Host-side mirror: highest version delivered per stream (re-send
    /// idempotence check lives here, with the receiver).
    applied: BTreeMap<(usize, usize), u64>,
    pending_acks: Vec<PendingApply>,
    /// Planner hysteresis per stream.
    level: BTreeMap<(usize, usize), Level>,
    clean_streak: BTreeMap<(usize, usize), u32>,
    round: u64,
}

impl FleetPlane<BinaryHeapQueue<EngineEvent>> {
    /// Build on the reference binary-heap queue.
    pub fn new(template: ExperimentSpec, cfg: FleetConfig) -> Self {
        Self::build(template, cfg)
    }
}

impl<Q: EventQueue<EngineEvent> + Default> FleetPlane<Q> {
    /// Shard the template and build one engine per host.
    pub fn build(template: ExperimentSpec, cfg: FleetConfig) -> Self {
        assert!(cfg.validate().is_ok(), "invalid fleet config: {:?}", cfg.validate());
        let hosts = (0..cfg.hosts)
            .map(|h| {
                let (spec, globals) = host_spec(&template, h, cfg.hosts);
                let commit = tenant_engine_commit(&spec);
                HostSlot { engine: Engine::<Q>::build(spec), globals, commit }
            })
            .collect();
        FleetPlane {
            cfg,
            template,
            hosts,
            dist: DeltaDistributor::new(),
            applied: BTreeMap::new(),
            pending_acks: Vec::new(),
            level: BTreeMap::new(),
            clean_streak: BTreeMap::new(),
            round: 0,
        }
    }

    /// Interchange period on the virtual clock.
    fn period(&self) -> Time {
        self.template.control_period * self.cfg.interchange_every.max(1)
    }

    /// Advance every host to `t` — the only parallel section. Hosts share
    /// no state between barriers, so sharding them over threads cannot
    /// reorder any host's own events.
    fn advance_all(&mut self, t: Time)
    where
        Q: Send,
    {
        let threads = if self.cfg.threads == 0 { self.hosts.len() } else { self.cfg.threads };
        let threads = threads.clamp(1, self.hosts.len().max(1));
        if threads <= 1 {
            for h in &mut self.hosts {
                h.engine.step_to(t);
            }
            return;
        }
        let chunk = self.hosts.len().div_ceil(threads);
        std::thread::scope(|scope| {
            for slice in self.hosts.chunks_mut(chunk) {
                scope.spawn(move || {
                    for h in slice {
                        h.engine.step_to(t);
                    }
                });
            }
        });
    }

    /// Collect ACKs due by barrier time `t`: a host acknowledges a batch on
    /// its first barrier at/after the batch's apply time (delivery +
    /// reconfiguration latency). Cumulative per stream.
    fn collect_acks(&mut self, t: Time) {
        let mut due: BTreeMap<(usize, usize), u64> = BTreeMap::new();
        self.pending_acks.retain(|p| {
            if p.apply_at <= t {
                let e = due.entry((p.host, p.class)).or_insert(0);
                *e = (*e).max(p.version);
                false
            } else {
                true
            }
        });
        for ((host, class), version) in due {
            self.dist.ack(&DirectiveAck { host, class, version, acked_at: t });
        }
    }

    /// The planning pass: decide each stream's envelope level from the
    /// host observability planes and publish deltas for changed (or
    /// refresh-due) streams. Sequential, host order, BTreeMap iteration —
    /// deterministic.
    fn plan(&mut self, t: Time) {
        if self.template.mode != Mode::Arcus {
            return; // envelopes only exist on the shaped architecture
        }
        let refresh = self.cfg.refresh_every > 0 && self.round % self.cfg.refresh_every == 0;
        let mut publishes: Vec<(usize, usize, Vec<Directive>)> = Vec::new();
        for (h, slot) in self.hosts.iter().enumerate() {
            let view = ObsView::of(slot.engine.obs());
            // Tenants on this host, in vm order, with their flows' local
            // positions.
            let mut tenants: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
            for (local, &g) in slot.globals.iter().enumerate() {
                tenants.entry(self.template.flows[g].vm).or_default().push(local);
            }
            for (vm, locals) in tenants {
                let violating = locals.iter().any(|&l| {
                    view.flow_attainment_ppm(l)
                        .map(|a| a < self.cfg.attainment_floor_ppm)
                        .unwrap_or(false)
                });
                let key = (h, vm);
                let current = self.level.get(&key).copied();
                let desired = if violating {
                    self.clean_streak.insert(key, 0);
                    Level::Boost
                } else if current == Some(Level::Boost) {
                    let streak = self.clean_streak.entry(key).or_insert(0);
                    *streak += 1;
                    if *streak >= self.cfg.clear_rounds { Level::Tight } else { Level::Boost }
                } else {
                    Level::Tight
                };
                if current == Some(desired) && !refresh {
                    continue;
                }
                let factor = match desired {
                    Level::Tight => self.cfg.tight_ceiling,
                    Level::Boost => self.cfg.boost_ceiling,
                };
                let directives: Vec<Directive> = slot
                    .commit
                    .iter()
                    .filter(|((v, _), _)| *v == vm)
                    .map(|(&(_, engine), &sum)| {
                        Directive::set_aggregate(t, engine, vm, sum, sum * factor)
                    })
                    .collect();
                if directives.is_empty() {
                    continue; // tenant has no byte-rated commitment here
                }
                self.level.insert(key, desired);
                publishes.push((h, vm, directives));
            }
        }
        for (h, vm, directives) in publishes {
            self.dist.publish(h, vm, t, directives);
        }
    }

    /// The delivery pass: offer every outstanding batch. An offer inside a
    /// drop window is lost (stays outstanding); otherwise it lands after
    /// the propagation delay. Only a version newer than the stream's last
    /// applied one is injected — re-sends racing an in-flight ACK are
    /// idempotent. Injected directives are re-stamped to their delivery
    /// time so in-host `directive_lag_max` still measures exactly the
    /// reconfiguration latency; the propagation component is ledgered as
    /// *staleness* by the distributor.
    fn deliver(&mut self, t: Time) {
        let delivery_at = t + self.cfg.propagation_delay;
        let dropped = self
            .cfg
            .drop_windows
            .iter()
            .any(|&(s, e)| delivery_at >= s && delivery_at < e);
        if dropped {
            for _ in 0..self.dist.outstanding().len() {
                self.dist.mark_dropped();
            }
            return;
        }
        let offers: Vec<(usize, usize, u64, Vec<Directive>)> = self
            .dist
            .outstanding()
            .iter()
            .map(|b| (b.host, b.class, b.version, b.directives.clone()))
            .collect();
        for (host, class, version, directives) in offers {
            self.dist.mark_delivered(host, class, version, delivery_at);
            let applied = self.applied.entry((host, class)).or_insert(0);
            if version <= *applied {
                continue; // receiver-side idempotence
            }
            *applied = version;
            for d in directives {
                let restamped = Directive { issued_at: delivery_at, kind: d.kind };
                self.hosts[host].engine.deliver_directive(delivery_at, restamped);
            }
            self.pending_acks.push(PendingApply {
                host,
                class,
                version,
                apply_at: delivery_at + self.template.reconfig_latency,
            });
        }
    }

    /// Run to the template's duration and produce the merged report.
    pub fn run(mut self) -> SystemReport
    where
        Q: Send,
    {
        let start = std::time::Instant::now();
        let duration = self.template.duration;
        let period = self.period();
        let mut t = period;
        while t < duration {
            self.round += 1;
            self.advance_all(t);
            self.collect_acks(t);
            self.plan(t);
            self.deliver(t);
            t += period;
        }
        self.advance_all(duration);
        let wall = start.elapsed().as_secs_f64();
        self.merge(wall)
    }

    /// Fold per-host reports into one fleet [`SystemReport`]: per-flow rows
    /// in template order, summed/max'd scalars, per-host rollups, and a
    /// merged observability snapshot (flows keyed back to template
    /// positions, engines offset per host, tenants owned by their host).
    fn merge(self, wall: f64) -> SystemReport {
        let n_hosts = self.hosts.len();
        let dist = self.dist;
        let mut rollups: Vec<HostRollup> = Vec::with_capacity(n_hosts);
        let mut per_flow_indexed = Vec::new();
        let mut pcie_up = 0.0;
        let mut pcie_down = 0.0;
        let mut accel_util = Vec::new();
        let mut nic_rx_dropped = 0u64;
        let mut fault_lo: Option<Time> = None;
        let mut fault_hi: Option<Time> = None;
        let mut events = 0u64;
        let mut peak_queue = 0usize;
        let mut lag_max = 0;
        let mut queue_name = "";
        let mut merged_obs = crate::obs::ObsSnapshot::default();
        for (h, slot) in self.hosts.into_iter().enumerate() {
            let globals = slot.globals;
            let report = slot.engine.finish(0.0);
            if h == 0 {
                queue_name = report.queue;
                merged_obs.control_period = report.obs.control_period;
                merged_obs.sample_every = report.obs.sample_every;
            }
            rollups.push(HostRollup {
                host: h,
                flows: globals.len(),
                events: report.events,
                peak_queue_depth: report.peak_queue_depth,
                nic_rx_dropped: report.nic_rx_dropped,
                directive_lag_max: report.directive_lag_max,
                directive_staleness_max: dist.host_staleness_max(h),
                series_digest: report.series_digest,
            });
            for (local, fr) in report.per_flow.into_iter().enumerate() {
                per_flow_indexed.push((globals[local], fr));
            }
            pcie_up += report.pcie_up_util;
            pcie_down += report.pcie_down_util;
            accel_util.extend(report.accel_util);
            nic_rx_dropped += report.nic_rx_dropped;
            if let Some((lo, hi)) = report.fault_window {
                fault_lo = Some(fault_lo.map_or(lo, |v: Time| v.min(lo)));
                fault_hi = Some(fault_hi.map_or(hi, |v: Time| v.max(hi)));
            }
            events += report.events;
            peak_queue = peak_queue.max(report.peak_queue_depth);
            lag_max = lag_max.max(report.directive_lag_max);
            let n_engines = report.obs.engines.len();
            for mut f in report.obs.flows {
                f.flow = globals[f.flow];
                f.engine += h * n_engines;
                merged_obs.flows.push(f);
            }
            for tnt in report.obs.tenants {
                if host_of(tnt.vm, n_hosts) == h {
                    merged_obs.tenants.push(tnt);
                }
            }
            for mut e in report.obs.engines {
                e.engine += h * n_engines;
                merged_obs.engines.push(e);
            }
        }
        per_flow_indexed.sort_by_key(|&(g, _)| g);
        merged_obs.flows.sort_by_key(|f| f.flow);
        merged_obs.tenants.sort_by_key(|t| t.vm);
        let series_digest = merged_obs.digest();
        SystemReport {
            mode: self.template.mode.name(),
            per_flow: per_flow_indexed.into_iter().map(|(_, fr)| fr).collect(),
            measured_span: self.template.duration - self.template.warmup,
            pcie_up_util: pcie_up / n_hosts as f64,
            pcie_down_util: pcie_down / n_hosts as f64,
            accel_util,
            nic_rx_dropped,
            fault_window: match (fault_lo, fault_hi) {
                (Some(lo), Some(hi)) => Some((lo, hi)),
                _ => fault_window(&self.template.faults),
            },
            directive_lag_max: lag_max,
            directive_staleness_max: dist.staleness_max(),
            host_rollups: rollups,
            events,
            peak_queue_depth: peak_queue,
            queue: queue_name,
            wall_secs: wall,
            series_digest,
            obs: merged_obs,
            // Fleet runs reject [population] at config validation; nothing
            // to merge.
            fairness: None,
        }
    }

    /// Protocol counters (published batches, re-send attempts) — demo /
    /// test read side. Call before `run` consumes the plane, or use the
    /// report's staleness fields afterwards.
    pub fn distributor(&self) -> &DeltaDistributor {
        &self.dist
    }
}

/// Build + run a fleet on the reference binary-heap queue.
pub fn run(template: &ExperimentSpec, cfg: &FleetConfig) -> SystemReport {
    FleetPlane::<BinaryHeapQueue<EngineEvent>>::build(template.clone(), cfg.clone()).run()
}

/// Build + run a fleet on a chosen queue discipline.
pub fn run_with<Q: EventQueue<EngineEvent> + Default + Send>(
    template: &ExperimentSpec,
    cfg: &FleetConfig,
) -> SystemReport {
    FleetPlane::<Q>::build(template.clone(), cfg.clone()).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::AccelModel;
    use crate::flow::{FlowSpec, Path, Slo, TrafficPattern};
    use crate::util::units::{Rate, MILLIS};

    fn template(hosts_worth: usize) -> ExperimentSpec {
        let accels = vec![AccelModel::ipsec_32g(), AccelModel::compress()];
        let flows: Vec<FlowSpec> = (0..hosts_worth * 2)
            .map(|i| {
                FlowSpec::new(
                    i,
                    i / 2,
                    Path::FunctionCall,
                    TrafficPattern::fixed(4096, 0.2, Rate::gbps(50.0)),
                    Slo::gbps(2.0),
                    i % 2,
                )
            })
            .collect();
        ExperimentSpec::new(Mode::Arcus, accels, flows)
            .with_duration(4 * MILLIS)
            .with_warmup(MILLIS)
            .with_hierarchy()
    }

    #[test]
    fn partitioning_is_by_vm_and_preserves_global_ids() {
        let t = template(4);
        let (s0, g0) = host_spec(&t, 0, 2);
        let (s1, g1) = host_spec(&t, 1, 2);
        assert_eq!(s0.flows.len() + s1.flows.len(), t.flows.len());
        for f in &s0.flows {
            assert_eq!(f.vm % 2, 0);
        }
        for f in &s1.flows {
            assert_eq!(f.vm % 2, 1);
        }
        // Global flow ids (and thus traffic streams) survive the shard.
        assert_eq!(s0.flows[0].id, t.flows[g0[0]].id);
        assert_eq!(s1.flows[0].id, t.flows[g1[0]].id);
        // A tenant's flows never straddle hosts.
        for (spec, h) in [(&s0, 0usize), (&s1, 1usize)] {
            for f in &spec.flows {
                assert_eq!(host_of(f.vm, 2), h);
            }
        }
    }

    #[test]
    fn component_faults_land_on_host_zero_rogue_follows_its_flow() {
        use crate::faults::FaultSpec;
        let mut t = template(4);
        t = t
            .with_fault(FaultSpec::new(
                FaultKind::AccelSlowdown { unit: 0, factor: 0.5 },
                2 * MILLIS,
                3 * MILLIS,
            ))
            .with_fault(FaultSpec::new(
                // Flow 2 belongs to vm 1 → host 1 under hosts=2.
                FaultKind::RogueTenant { flow: 2 },
                2 * MILLIS,
                3 * MILLIS,
            ));
        let (s0, _) = host_spec(&t, 0, 2);
        let (s1, _) = host_spec(&t, 1, 2);
        assert_eq!(s0.faults.len(), 1);
        assert!(matches!(s0.faults[0].kind, FaultKind::AccelSlowdown { .. }));
        assert_eq!(s1.faults.len(), 1);
        match s1.faults[0].kind {
            FaultKind::RogueTenant { flow } => {
                // Remapped to host 1's local index for global flow 2.
                assert_eq!(s1.flows[flow].id, 2);
            }
            _ => panic!("expected rogue tenant on host 1"),
        }
    }

    #[test]
    fn fleet_run_merges_flows_in_template_order() {
        let t = template(4);
        let cfg = FleetConfig { hosts: 2, threads: 1, ..FleetConfig::default() };
        let r = run(&t, &cfg);
        let ids: Vec<usize> = r.per_flow.iter().map(|f| f.flow).collect();
        assert_eq!(ids, (0..8).collect::<Vec<_>>());
        assert_eq!(r.host_rollups.len(), 2);
        assert_eq!(r.host_rollups[0].flows + r.host_rollups[1].flows, 8);
        assert_eq!(
            r.events,
            r.host_rollups.iter().map(|h| h.events).sum::<u64>()
        );
        // Healthy run, zero propagation delay: envelopes were distributed
        // (refresh keeps streams alive) but nothing was stale.
        assert_eq!(r.directive_staleness_max, 0);
    }

    #[test]
    fn thread_count_does_not_change_the_report() {
        let t = template(8);
        let serial = run(
            &t,
            &FleetConfig { hosts: 4, threads: 1, ..FleetConfig::default() },
        );
        let parallel = run(
            &t,
            &FleetConfig { hosts: 4, threads: 0, ..FleetConfig::default() },
        );
        assert_eq!(serial.canonical(), parallel.canonical());
    }

    #[test]
    fn propagation_delay_is_ledgered_as_staleness() {
        let t = template(4);
        let cfg = FleetConfig {
            hosts: 2,
            threads: 1,
            propagation_delay: 50 * crate::util::units::MICROS,
            ..FleetConfig::default()
        };
        let r = run(&t, &cfg);
        assert_eq!(r.directive_staleness_max, 50 * crate::util::units::MICROS);
        // Staleness is the distribution tier's ledger; the in-host apply
        // lag stays pinned at the reconfiguration latency.
        assert!(r.directive_lag_max <= t.reconfig_latency);
    }
}
