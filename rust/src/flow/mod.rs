//! The accelerator-flow abstraction (§3.3) and traffic patterns.
//!
//! A *flow* is the unit of SLO management: a stream of accelerator
//! invocations from one VM over one path. Flows carry a [`Path`] (which
//! communication route the invocations take — Fig 2), a [`TrafficPattern`]
//! (message-size and injection-rate behaviour, the knobs Table 1 sweeps),
//! and an [`Slo`] target. The [`generator::TrafficGen`] turns a pattern into
//! a deterministic arrival stream.

pub mod generator;
pub mod pattern;

pub use generator::TrafficGen;
pub use pattern::{Burstiness, SizeDist, TrafficPattern};

use crate::util::units::Rate;

/// Flow identifier (index into the per-flow tables).
pub type FlowId = usize;

/// Invocation paths from Fig 2.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Path {
    /// ①② Loop-back through host memory: DMA read payload Down, result
    /// written back Up.
    FunctionCall,
    /// ③ TX inline: host pushes data out through the accelerator.
    InlineNicTx,
    /// ③ RX inline: packets arrive from the wire, accelerator processes,
    /// DMA-writes to host memory (loads the Up direction only).
    InlineNicRx,
    /// ④ Peer-to-peer with another device (NVMe in our prototypes).
    InlineP2p,
}

impl Path {
    pub fn name(self) -> &'static str {
        match self {
            Path::FunctionCall => "function_call",
            Path::InlineNicTx => "inline_nic_tx",
            Path::InlineNicRx => "inline_nic_rx",
            Path::InlineP2p => "inline_p2p",
        }
    }

    pub fn by_name(name: &str) -> Option<Path> {
        Some(match name {
            "function_call" => Path::FunctionCall,
            "inline_nic_tx" => Path::InlineNicTx,
            "inline_nic_rx" => Path::InlineNicRx,
            "inline_p2p" => Path::InlineP2p,
            _ => return None,
        })
    }

    pub const ALL: [Path; 4] = [
        Path::FunctionCall,
        Path::InlineNicTx,
        Path::InlineNicRx,
        Path::InlineP2p,
    ];
}

/// An SLO target for one flow: a throughput (or IOPS) number under a
/// percentile guarantee (§1: "an SLO specifies (1) a precise performance
/// number and (2) low variance").
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Slo {
    /// Sustained bandwidth target.
    Throughput { target: Rate, percentile: f64 },
    /// Operation-rate target.
    Iops { target: f64, percentile: f64 },
    /// Tail-latency bound (Fig 9's 64 B latency-critical flow).
    Latency { max_ps: u64, percentile: f64 },
    /// Opportunistic / best-effort (§6's no-guarantee class; the live
    /// migration background job).
    BestEffort,
}

impl Slo {
    pub fn gbps(g: f64) -> Slo {
        Slo::Throughput {
            target: Rate::gbps(g),
            percentile: 99.0,
        }
    }
    pub fn iops(k: f64) -> Slo {
        Slo::Iops {
            target: k,
            percentile: 99.0,
        }
    }

    /// The shaping rate (units/sec) this SLO requires, and its mode.
    pub fn required_rate(&self) -> Option<(f64, crate::shaping::ShapeMode)> {
        match *self {
            Slo::Throughput { target, .. } => {
                Some((target.as_bits_per_sec() / 8.0, crate::shaping::ShapeMode::Gbps))
            }
            Slo::Iops { target, .. } => Some((target, crate::shaping::ShapeMode::Iops)),
            Slo::Latency { .. } | Slo::BestEffort => None,
        }
    }
}

/// What a flow's invocations actually do.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FlowKind {
    /// Invoke an accelerator (the default).
    #[default]
    Accel,
    /// NVMe read through the inline-P2P path (Fig 6, Fig 11b).
    StorageRead,
    /// NVMe write through the inline-P2P path.
    StorageWrite,
}

/// Static description of one flow (what a VM registers with the runtime).
#[derive(Debug, Clone)]
pub struct FlowSpec {
    pub id: FlowId,
    /// Owning VM (for per-VM aggregation in reports).
    pub vm: usize,
    pub path: Path,
    pub pattern: TrafficPattern,
    pub slo: Slo,
    /// Which accelerator this flow invokes (index into the system's list).
    pub accel: usize,
    pub kind: FlowKind,
    /// Strict-priority class for the PANIC baseline (lower = higher).
    pub priority: u32,
}

impl FlowSpec {
    /// Accelerator flow with default priority.
    pub fn new(id: FlowId, vm: usize, path: Path, pattern: TrafficPattern, slo: Slo, accel: usize) -> Self {
        FlowSpec {
            id,
            vm,
            path,
            pattern,
            slo,
            accel,
            kind: FlowKind::Accel,
            priority: 1,
        }
    }

    /// Storage flow (inline-P2P).
    pub fn storage(id: FlowId, vm: usize, pattern: TrafficPattern, slo: Slo, kind: FlowKind) -> Self {
        debug_assert!(kind != FlowKind::Accel);
        FlowSpec {
            id,
            vm,
            path: Path::InlineP2p,
            pattern,
            slo,
            accel: 0,
            kind,
            priority: 1,
        }
    }

    pub fn with_priority(mut self, p: u32) -> Self {
        self.priority = p;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_name_roundtrip() {
        for p in Path::ALL {
            assert_eq!(Path::by_name(p.name()), Some(p));
        }
        assert_eq!(Path::by_name("bogus"), None);
    }

    #[test]
    fn slo_required_rate() {
        let (rate, mode) = Slo::gbps(10.0).required_rate().unwrap();
        assert!((rate - 1.25e9).abs() < 1.0);
        assert_eq!(mode, crate::shaping::ShapeMode::Gbps);
        let (iops, mode) = Slo::iops(300_000.0).required_rate().unwrap();
        assert_eq!(iops, 300_000.0);
        assert_eq!(mode, crate::shaping::ShapeMode::Iops);
        assert!(Slo::BestEffort.required_rate().is_none());
    }
}
