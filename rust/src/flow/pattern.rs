//! Traffic patterns: message-size distributions, injection load, burstiness.
//!
//! Table 1 parameterizes each VM's stream as `{size, load}` where load is a
//! fraction of a reference line rate; real tenants add burstiness on top.
//! Patterns are deliberately *descriptive* (what a VM does), not normative —
//! Arcus's whole point is that the interface re-shapes PatternA into
//! PatternA′ regardless of what tenants choose.

use crate::util::units::{Rate, Time, SECONDS};
use crate::util::Rng;

/// Message-size distribution.
#[derive(Debug, Clone, PartialEq)]
pub enum SizeDist {
    /// Every message the same size (the paper's case studies).
    Fixed(u64),
    /// Uniform over [lo, hi].
    Uniform { lo: u64, hi: u64 },
    /// Two sizes with probability split (tiny-RPC + bulk mixtures).
    Bimodal { a: u64, b: u64, p_a: f64 },
    /// Choice from a set with equal probability.
    Choice(Vec<u64>),
}

impl SizeDist {
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        match self {
            SizeDist::Fixed(s) => *s,
            SizeDist::Uniform { lo, hi } => rng.range_u64(*lo, *hi),
            SizeDist::Bimodal { a, b, p_a } => {
                if rng.chance(*p_a) {
                    *a
                } else {
                    *b
                }
            }
            SizeDist::Choice(v) => *rng.choose(v),
        }
    }

    /// Expected message size.
    pub fn mean(&self) -> f64 {
        match self {
            SizeDist::Fixed(s) => *s as f64,
            SizeDist::Uniform { lo, hi } => (*lo + *hi) as f64 / 2.0,
            SizeDist::Bimodal { a, b, p_a } => {
                *a as f64 * p_a + *b as f64 * (1.0 - p_a)
            }
            SizeDist::Choice(v) => {
                v.iter().sum::<u64>() as f64 / v.len() as f64
            }
        }
    }
}

/// Inter-arrival behaviour.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Burstiness {
    /// Constant spacing (paced traffic generator, the Table 1 studies).
    Paced,
    /// Poisson arrivals (open-loop server workloads).
    Poisson,
    /// On/off bursts: `burst_len` back-to-back messages, then idle to keep
    /// the long-run load (Fig 9's "bursty tiny messages").
    OnOff { burst_len: u32 },
}

/// A complete traffic pattern.
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficPattern {
    pub sizes: SizeDist,
    /// Injection load as a fraction of `line_rate` (Table 1's `load`).
    pub load: f64,
    /// Reference line rate the load fraction is relative to.
    pub line_rate: Rate,
    pub burst: Burstiness,
}

impl TrafficPattern {
    /// Table 1 style: fixed size, load fraction of a line rate, paced.
    pub fn fixed(size: u64, load: f64, line_rate: Rate) -> Self {
        TrafficPattern {
            sizes: SizeDist::Fixed(size),
            load,
            line_rate,
            burst: Burstiness::Paced,
        }
    }

    /// Offered byte rate.
    pub fn offered(&self) -> Rate {
        Rate(self.line_rate.0 * self.load)
    }

    /// Mean messages/sec implied by the pattern.
    pub fn mean_mps(&self) -> f64 {
        self.offered().as_bits_per_sec() / 8.0 / self.sizes.mean()
    }

    /// Mean inter-arrival gap in ps.
    pub fn mean_gap(&self) -> Time {
        (SECONDS as f64 / self.mean_mps()).round() as Time
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_pattern_rates() {
        let p = TrafficPattern::fixed(1500, 0.5, Rate::gbps(50.0));
        assert!((p.offered().as_gbps() - 25.0).abs() < 1e-9);
        let mps = p.mean_mps();
        assert!((mps - 25e9 / 8.0 / 1500.0).abs() < 1.0);
        // gap * mps == 1 second
        assert!((p.mean_gap() as f64 * mps / SECONDS as f64 - 1.0).abs() < 1e-6);
    }

    #[test]
    fn size_dist_sampling() {
        let mut rng = Rng::new(5);
        assert_eq!(SizeDist::Fixed(640).sample(&mut rng), 640);
        for _ in 0..1000 {
            let s = SizeDist::Uniform { lo: 64, hi: 1500 }.sample(&mut rng);
            assert!((64..=1500).contains(&s));
        }
        let bi = SizeDist::Bimodal {
            a: 64,
            b: 4096,
            p_a: 0.9,
        };
        let small = (0..10_000).filter(|_| bi.sample(&mut rng) == 64).count();
        assert!((8_800..9_200).contains(&small), "small={small}");
    }

    #[test]
    fn size_dist_means() {
        assert_eq!(SizeDist::Fixed(100).mean(), 100.0);
        assert_eq!(SizeDist::Uniform { lo: 0, hi: 100 }.mean(), 50.0);
        assert_eq!(
            SizeDist::Bimodal {
                a: 0,
                b: 100,
                p_a: 0.75
            }
            .mean(),
            25.0
        );
        assert_eq!(SizeDist::Choice(vec![10, 20, 30]).mean(), 20.0);
    }
}
