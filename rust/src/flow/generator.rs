//! Deterministic traffic generator: pattern → arrival stream.
//!
//! Mirrors the paper's on-FPGA traffic generator (§3.1): each flow owns an
//! independent RNG stream, so experiments are reproducible and adding a flow
//! never perturbs another flow's arrivals.

use super::pattern::{Burstiness, TrafficPattern};
use crate::util::units::{Time, SECONDS};
use crate::util::Rng;

/// One generated arrival.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Arrival {
    pub at: Time,
    pub bytes: u64,
}

/// Stateful arrival generator for one flow.
#[derive(Debug, Clone)]
pub struct TrafficGen {
    pattern: TrafficPattern,
    rng: Rng,
    next_at: Time,
    /// Remaining messages in the current burst (OnOff mode).
    burst_left: u32,
    generated: u64,
}

impl TrafficGen {
    pub fn new(pattern: TrafficPattern, seed: u64, flow: u64) -> Self {
        TrafficGen {
            pattern,
            rng: Rng::for_stream(seed, 0x7F0 + flow),
            next_at: 0,
            burst_left: 0,
            generated: 0,
        }
    }

    pub fn pattern(&self) -> &TrafficPattern {
        &self.pattern
    }

    /// Produce the next arrival at or after the previous one.
    pub fn next(&mut self) -> Arrival {
        let bytes = self.pattern.sizes.sample(&mut self.rng);
        let at = self.next_at;
        // Gap to the *next* arrival depends on this message's size so the
        // byte rate (not message rate) tracks the configured load.
        let this_gap = bytes as f64 * 8.0 / self.pattern.offered().as_bits_per_sec()
            * SECONDS as f64;
        let gap = match self.pattern.burst {
            Burstiness::Paced => this_gap,
            Burstiness::Poisson => self.rng.exponential(this_gap),
            Burstiness::OnOff { burst_len } => {
                if self.burst_left == 0 {
                    self.burst_left = burst_len;
                }
                self.burst_left -= 1;
                if self.burst_left > 0 {
                    0.0 // back-to-back within a burst
                } else {
                    this_gap * burst_len as f64 // idle to restore the mean
                }
            }
        };
        self.next_at = at + gap.round().max(0.0) as Time;
        self.generated += 1;
        Arrival { at, bytes }
    }

    /// Generate all arrivals with `at < until`.
    pub fn take_until(&mut self, until: Time) -> Vec<Arrival> {
        let mut out = Vec::new();
        while self.next_at < until {
            out.push(self.next());
        }
        out
    }

    pub fn generated(&self) -> u64 {
        self.generated
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::pattern::SizeDist;
    use crate::util::units::{Rate, MILLIS};

    fn rate_of(arrivals: &[Arrival]) -> f64 {
        let bytes: u64 = arrivals.iter().map(|a| a.bytes).sum();
        let span = arrivals.last().unwrap().at - arrivals[0].at;
        bytes as f64 * 8.0 * SECONDS as f64 / span as f64
    }

    #[test]
    fn paced_rate_tracks_load() {
        for load in [0.1, 0.5, 0.9] {
            let p = TrafficPattern::fixed(1500, load, Rate::gbps(50.0));
            let mut g = TrafficGen::new(p, 1, 0);
            let arrivals = g.take_until(2 * MILLIS);
            let rate = rate_of(&arrivals);
            let target = 50e9 * load;
            assert!(
                ((rate - target) / target).abs() < 0.01,
                "load={load}: rate={:.2}G",
                rate / 1e9
            );
        }
    }

    #[test]
    fn poisson_rate_tracks_load_with_variance() {
        let mut p = TrafficPattern::fixed(1500, 0.4, Rate::gbps(50.0));
        p.burst = Burstiness::Poisson;
        let mut g = TrafficGen::new(p, 2, 0);
        let arrivals = g.take_until(5 * MILLIS);
        let rate = rate_of(&arrivals);
        assert!(((rate - 20e9) / 20e9).abs() < 0.05, "rate={:.2}G", rate / 1e9);
        // And gaps are NOT constant.
        let gaps: Vec<u64> = arrivals.windows(2).map(|w| w[1].at - w[0].at).collect();
        let distinct: std::collections::HashSet<_> = gaps.iter().collect();
        assert!(distinct.len() > gaps.len() / 4);
    }

    #[test]
    fn onoff_bursts_are_back_to_back() {
        let mut p = TrafficPattern::fixed(64, 0.2, Rate::gbps(50.0));
        p.burst = Burstiness::OnOff { burst_len: 16 };
        let mut g = TrafficGen::new(p, 3, 0);
        let arrivals = g.take_until(MILLIS);
        // Long-run rate still tracks.
        let rate = rate_of(&arrivals);
        assert!(((rate - 10e9) / 10e9).abs() < 0.05, "rate={:.2}G", rate / 1e9);
        // Bursts: 15 of every 16 gaps are zero.
        let zeros = arrivals
            .windows(2)
            .filter(|w| w[1].at == w[0].at)
            .count() as f64;
        let frac = zeros / (arrivals.len() - 1) as f64;
        assert!((0.9..0.97).contains(&frac), "zero-gap frac={frac}");
    }

    #[test]
    fn mixed_sizes_keep_byte_rate() {
        let p = TrafficPattern {
            sizes: SizeDist::Choice(vec![64, 256, 1500, 4096]),
            load: 0.5,
            line_rate: Rate::gbps(40.0),
            burst: Burstiness::Paced,
        };
        let mut g = TrafficGen::new(p, 4, 0);
        let arrivals = g.take_until(5 * MILLIS);
        let rate = rate_of(&arrivals);
        assert!(((rate - 20e9) / 20e9).abs() < 0.03, "rate={:.2}G", rate / 1e9);
    }

    #[test]
    fn independent_flows_decorrelated() {
        let p = TrafficPattern::fixed(1500, 0.5, Rate::gbps(50.0));
        let a: Vec<_> = TrafficGen::new(p.clone(), 9, 0).take_until(MILLIS);
        let b: Vec<_> = TrafficGen::new(p, 9, 1).take_until(MILLIS);
        assert_eq!(a.len(), b.len()); // same deterministic pacing
        // but different streams would differ under Poisson:
        let mut pp = TrafficPattern::fixed(1500, 0.5, Rate::gbps(50.0));
        pp.burst = Burstiness::Poisson;
        let a: Vec<_> = TrafficGen::new(pp.clone(), 9, 0).take_until(MILLIS);
        let b: Vec<_> = TrafficGen::new(pp, 9, 1).take_until(MILLIS);
        assert_ne!(a, b);
    }

    #[test]
    fn reproducible() {
        let mut p = TrafficPattern::fixed(256, 0.3, Rate::gbps(50.0));
        p.burst = Burstiness::Poisson;
        let a: Vec<_> = TrafficGen::new(p.clone(), 42, 5).take_until(MILLIS);
        let b: Vec<_> = TrafficGen::new(p, 42, 5).take_until(MILLIS);
        assert_eq!(a, b);
    }
}
