//! Versioned, ACKed, delta-only directive distribution — the fleet tier's
//! wire vocabulary, modeled on Envoy's incremental xDS protocol.
//!
//! The fleet planner never ships whole config snapshots. Each update is a
//! [`DirectiveBatch`] — a *delta* for one `(host, resource class)` stream,
//! stamped with that stream's monotonically increasing config version. A
//! host acknowledges the highest version it has applied on its next control
//! tick ([`DirectiveAck`]); the [`DeltaDistributor`] keeps every un-ACKed
//! batch outstanding and re-offers it each distribution round, so deltas
//! survive drop windows (partial control-plane outages) by retransmission.
//!
//! Re-sends are made idempotent by the receiver, not the sender: a host
//! applies a batch only if its version is newer than the stream's last
//! applied version, so a delta that was delivered but whose ACK has not yet
//! made it back is re-sent harmlessly. The distributor records *staleness*
//! — publication to first successful delivery — per batch; the worst case
//! surfaces in `SystemReport::directive_staleness_max` and is the quantity
//! the propagation-lag experiments sweep.

use std::collections::BTreeMap;

use crate::util::units::Time;

use super::control::Directive;

/// Stream id: one independently versioned delta stream per
/// `(host, resource class)`. The fleet planner uses the tenant (VM) id as
/// the resource class, mirroring xDS's per-resource-type version counters.
pub type StreamId = (usize, usize);

/// One versioned delta for a single `(host, class)` stream.
#[derive(Debug, Clone)]
pub struct DirectiveBatch {
    /// Destination host.
    pub host: usize,
    /// Resource class (tenant VM id) this delta reconfigures.
    pub class: usize,
    /// Stream version: strictly increasing per `(host, class)`, starting
    /// at 1. A host applies the batch only when `version` exceeds the
    /// stream's last applied version.
    pub version: u64,
    /// Virtual time the fleet planner published the delta.
    pub published_at: Time,
    /// The directives themselves (applied atomically, in order).
    pub directives: Vec<Directive>,
    /// First successful delivery time, once one lands (drop windows can
    /// delay this across several re-send rounds).
    pub delivered_at: Option<Time>,
}

impl DirectiveBatch {
    /// Publication → first-successful-delivery staleness; `None` until the
    /// batch has landed.
    pub fn staleness(&self) -> Option<Time> {
        self.delivered_at.map(|t| t.saturating_sub(self.published_at))
    }
}

/// A host's acknowledgement of the highest version it has applied on one
/// stream, sent on its next control tick after the apply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DirectiveAck {
    /// Acknowledging host.
    pub host: usize,
    /// Stream resource class.
    pub class: usize,
    /// Highest applied version (cumulative: ACKing v implicitly ACKs all
    /// earlier versions of the stream).
    pub version: u64,
    /// Virtual time the ACK was emitted.
    pub acked_at: Time,
}

/// Sender-side state of the incremental distribution protocol: per-stream
/// version counters, the outstanding (published, un-ACKed) window, and the
/// staleness ledger.
///
/// Deterministic by construction: all iteration is over `BTreeMap`s /
/// publish-ordered `Vec`s, so the fleet's distribution rounds replay
/// byte-identically.
#[derive(Debug, Default)]
pub struct DeltaDistributor {
    next_version: BTreeMap<StreamId, u64>,
    acked: BTreeMap<StreamId, u64>,
    /// Published batches not yet ACKed, in publish order.
    outstanding: Vec<DirectiveBatch>,
    staleness_max: Time,
    per_host_staleness: BTreeMap<usize, Time>,
    published_total: u64,
    resend_total: u64,
}

impl DeltaDistributor {
    pub fn new() -> Self {
        Self::default()
    }

    /// Publish a delta on `(host, class)`: assigns the stream's next
    /// version and enqueues the batch for delivery. Returns the version.
    pub fn publish(
        &mut self,
        host: usize,
        class: usize,
        published_at: Time,
        directives: Vec<Directive>,
    ) -> u64 {
        let v = self.next_version.entry((host, class)).or_insert(0);
        *v += 1;
        let version = *v;
        self.outstanding.push(DirectiveBatch {
            host,
            class,
            version,
            published_at,
            directives,
            delivered_at: None,
        });
        self.published_total += 1;
        version
    }

    /// Every batch published but not yet ACKed, in publish order — the
    /// sender's re-offer set for the current distribution round.
    pub fn outstanding(&self) -> &[DirectiveBatch] {
        &self.outstanding
    }

    /// Record a successful delivery of `(host, class, version)` at `at`.
    /// Only the *first* delivery sets the batch's staleness (re-sends of an
    /// already-delivered-but-un-ACKed batch are idempotent at the host and
    /// must not distort the ledger). Deliveries after a round of drops
    /// count as re-sends for the protocol counters.
    pub fn mark_delivered(&mut self, host: usize, class: usize, version: u64, at: Time) {
        for b in &mut self.outstanding {
            if b.host == host && b.class == class && b.version == version {
                if b.delivered_at.is_none() {
                    b.delivered_at = Some(at);
                    let s = at.saturating_sub(b.published_at);
                    self.staleness_max = self.staleness_max.max(s);
                    let h = self.per_host_staleness.entry(host).or_insert(0);
                    *h = (*h).max(s);
                } else {
                    self.resend_total += 1;
                }
                return;
            }
        }
    }

    /// Record a dropped (lost) send attempt; the batch stays outstanding
    /// and will be re-offered next round.
    pub fn mark_dropped(&mut self) {
        self.resend_total += 1;
    }

    /// Ingest a host ACK: raises the stream's acked version monotonically
    /// (a stale or duplicate ACK is a no-op) and retires every outstanding
    /// batch at or below it.
    pub fn ack(&mut self, ack: &DirectiveAck) {
        let entry = self.acked.entry((ack.host, ack.class)).or_insert(0);
        if ack.version <= *entry {
            return;
        }
        *entry = ack.version;
        self.outstanding.retain(|b| {
            b.host != ack.host || b.class != ack.class || b.version > ack.version
        });
    }

    /// Highest ACKed version on a stream (0 = nothing ACKed yet).
    pub fn acked_version(&self, host: usize, class: usize) -> u64 {
        self.acked.get(&(host, class)).copied().unwrap_or(0)
    }

    /// Worst publish → first-delivery staleness across all batches so far.
    pub fn staleness_max(&self) -> Time {
        self.staleness_max
    }

    /// Worst staleness among batches addressed to `host`.
    pub fn host_staleness_max(&self, host: usize) -> Time {
        self.per_host_staleness.get(&host).copied().unwrap_or(0)
    }

    /// Total batches published.
    pub fn published_total(&self) -> u64 {
        self.published_total
    }

    /// Total re-send attempts (drops + redundant deliveries).
    pub fn resend_total(&self) -> u64 {
        self.resend_total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn versions_are_monotonic_and_per_stream() {
        let mut d = DeltaDistributor::new();
        assert_eq!(d.publish(0, 7, 100, Vec::new()), 1);
        assert_eq!(d.publish(0, 7, 200, Vec::new()), 2);
        assert_eq!(d.publish(1, 7, 200, Vec::new()), 1, "streams are per (host, class)");
        assert_eq!(d.publish(0, 8, 300, Vec::new()), 1);
        assert_eq!(d.outstanding().len(), 4);
    }

    #[test]
    fn unacked_batches_stay_outstanding_until_cumulative_ack() {
        let mut d = DeltaDistributor::new();
        d.publish(0, 1, 100, Vec::new());
        d.publish(0, 1, 200, Vec::new());
        d.publish(0, 1, 300, Vec::new());
        // ACK of v2 is cumulative: retires v1 and v2, keeps v3 for re-send.
        d.ack(&DirectiveAck { host: 0, class: 1, version: 2, acked_at: 400 });
        let left: Vec<u64> = d.outstanding().iter().map(|b| b.version).collect();
        assert_eq!(left, vec![3]);
        assert_eq!(d.acked_version(0, 1), 2);
        // A stale ACK neither regresses the version nor resurrects batches.
        d.ack(&DirectiveAck { host: 0, class: 1, version: 1, acked_at: 500 });
        assert_eq!(d.acked_version(0, 1), 2);
        assert_eq!(d.outstanding().len(), 1);
    }

    #[test]
    fn staleness_records_first_delivery_only() {
        let mut d = DeltaDistributor::new();
        d.publish(0, 1, 1_000, Vec::new());
        // Two rounds of drops, then delivery on the third offer.
        d.mark_dropped();
        d.mark_dropped();
        d.mark_delivered(0, 1, 1, 4_000);
        assert_eq!(d.staleness_max(), 3_000);
        assert_eq!(d.host_staleness_max(0), 3_000);
        assert_eq!(d.host_staleness_max(9), 0);
        // A redundant re-delivery (ACK still in flight) must not inflate
        // the ledger.
        d.mark_delivered(0, 1, 1, 9_000);
        assert_eq!(d.staleness_max(), 3_000);
        assert_eq!(d.resend_total(), 3);
        assert_eq!(d.published_total(), 1);
    }
}
