//! [`AdaptiveControlPlane`]: closed-loop traffic shaping over the Arcus
//! planner, driven by the observability plane's series.
//!
//! The static planner ([`ArcusControlPlane`]) reacts to SLO violations by
//! *boosting* a violating flow's shaper toward `max_boost × SLO` — correct
//! when the flow itself under-fetches, but counter-productive when the
//! engine is degraded (a fault, a flapping link): boosting offered load
//! into a slow engine only grows queues and explodes tail latency. The
//! adaptive plane closes the loop with the telemetry a [`TickContext`]
//! now carries, in the bi-level shape of Autothrottle (fast lightweight
//! per-entity controllers under a slow global re-planner):
//!
//! - **Fast tier** (every control tick): per committed flow, an
//!   additive-increase / multiplicative-decrease controller keyed on the
//!   obs series' attainment-ppm and queue-depth trend. Under-attainment
//!   with a *growing* queue means the engine cannot keep up → back off
//!   multiplicatively (never below the flow's guarantee, its SLO rate);
//!   under-attainment with a stable or draining queue means capacity is
//!   back → increase additively to drain backlog; a flow *meeting* its SLO
//!   while its queue still holds a backlog gets the same catch-up ramp —
//!   the static planner would decay it back to ~SLO and leave fault
//!   backlog (and its tail latency) parked in the queue. Every nudge is
//!   clamped to `[guarantee, max_ceiling × SLO]`, further capped by the
//!   tenant aggregate under hierarchy. Meeting flows with drained queues
//!   are released to the inner planner's decay-toward-SLO.
//! - **Slow tier** (every `replan_every` ticks, hierarchical mode): re-plan
//!   per-(engine, tenant) `SetAggregate` envelopes from windowed usage —
//!   guarantees stay pinned to the committed sums from
//!   [`planner::tenant_aggregates`] (the safety floor: programmed
//!   guarantee sums never exceed the admission budget), while ceilings
//!   redistribute the engine's head-room toward tenants that actually
//!   used bytes in the last window.
//!
//! Stability: decrease is multiplicative and bounded below (guarantee),
//! increase is additive and bounded above (ceiling, tenant aggregate), and
//! meeting flows converge via the inner planner's decay — so the
//! controller cannot oscillate unboundedly. Every decision is a function
//! of DES-scheduled state only (tick counter, status table, obs series),
//! so adaptivity preserves byte-identical reports across event-queue
//! disciplines.

use crate::coordinator::planner;
use crate::flow::{FlowId, Slo};

use super::arcus::ArcusControlPlane;
use super::control::{
    Admitted, ApiError, ControlPlane, Directive, DirectiveKind, FlowStatusView, RegisterRequest,
    TickContext,
};

/// Gains and periods of the bi-level controller. All knobs validate via
/// [`AdaptiveConfig::validate`]; the defaults are the tuning the adaptive
/// golden tests and benchmarks pin.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdaptiveConfig {
    /// Fast-tier additive increase per tick, as a fraction of the flow's
    /// SLO rate (bounded ramp while draining backlog).
    pub increase_step: f64,
    /// Fast-tier multiplicative decrease applied while the engine cannot
    /// keep up (queue growing under violation).
    pub decrease_factor: f64,
    /// Fast-tier cap on any flow's shaped rate, relative to its SLO rate.
    pub max_ceiling: f64,
    /// Slow-tier period: re-plan tenant aggregates every K control ticks.
    pub replan_every: u64,
    /// Attainment dead-band around 1_000_000 ppm: within it a flow counts
    /// as meeting and the fast tier holds (mirrors the status-table
    /// tolerance so the two state machines agree).
    pub deadband_ppm: u64,
    /// Queue depth (messages, incl. in-flight fetches) above which a flow
    /// counts as *backlogged*: a meeting flow with at least this much
    /// queued demand gets the catch-up ramp instead of the inner decay.
    /// Must exceed the steady-state fetch-pipeline depth (~16) so normal
    /// pipelining never reads as backlog.
    pub backlog_depth: u64,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            increase_step: 0.02,
            decrease_factor: 0.85,
            max_ceiling: 1.25,
            replan_every: 10,
            deadband_ppm: 20_000,
            backlog_depth: 64,
        }
    }
}

impl AdaptiveConfig {
    /// Validate ranges; returns a human-readable complaint on the first
    /// violation (config-file parsing surfaces it verbatim).
    pub fn validate(&self) -> Result<(), String> {
        if !(self.increase_step > 0.0 && self.increase_step <= 1.0) {
            return Err(format!(
                "adaptive.increase_step must be in (0, 1], got {}",
                self.increase_step
            ));
        }
        if !(self.decrease_factor > 0.0 && self.decrease_factor < 1.0) {
            return Err(format!(
                "adaptive.decrease_factor must be in (0, 1), got {}",
                self.decrease_factor
            ));
        }
        if !(self.max_ceiling >= 1.0) {
            return Err(format!(
                "adaptive.max_ceiling must be >= 1.0 (the SLO itself), got {}",
                self.max_ceiling
            ));
        }
        if self.replan_every == 0 {
            return Err("adaptive.replan_every must be >= 1 tick".into());
        }
        if self.deadband_ppm >= 1_000_000 {
            return Err(format!(
                "adaptive.deadband_ppm must be < 1000000, got {}",
                self.deadband_ppm
            ));
        }
        if self.backlog_depth == 0 {
            return Err("adaptive.backlog_depth must be >= 1 message".into());
        }
        Ok(())
    }
}

/// The closed-loop wrapper: an [`ArcusControlPlane`] plus AIMD fast-tier
/// state and the slow-tier re-planner.
pub struct AdaptiveControlPlane {
    inner: ArcusControlPlane,
    cfg: AdaptiveConfig,
    /// Control ticks seen (drives the slow-tier period).
    ticks: u64,
    /// Last observed queue depth per flow (the trend signal).
    last_depth: std::collections::BTreeMap<FlowId, u64>,
    /// Rates the fast tier currently commands, per overridden flow. While
    /// a flow is overridden the wrapper — not the inner planner's row — is
    /// the authority: the inner tick decays a boosted *meeting* flow every
    /// tick (mutating its row before the fast tier runs), and reading the
    /// decayed row back would stall the catch-up ramp just above the SLO.
    /// Entries are dropped when a flow is released to the inner decay.
    commanded: std::collections::BTreeMap<FlowId, f64>,
    /// Tenant envelopes the slow tier last announced, keyed by
    /// `(engine, tenant)` — re-plans only emit deltas.
    announced: std::collections::BTreeMap<(usize, usize), (f64, f64)>,
}

impl AdaptiveControlPlane {
    /// Wrap an Arcus plane with the given controller tuning.
    pub fn new(inner: ArcusControlPlane, cfg: AdaptiveConfig) -> Self {
        AdaptiveControlPlane {
            inner,
            cfg,
            ticks: 0,
            last_depth: std::collections::BTreeMap::new(),
            commanded: std::collections::BTreeMap::new(),
            announced: std::collections::BTreeMap::new(),
        }
    }

    /// The wrapped static plane (tests / observability).
    pub fn inner(&self) -> &ArcusControlPlane {
        &self.inner
    }

    /// The controller tuning in force.
    pub fn adaptive_cfg(&self) -> &AdaptiveConfig {
        &self.cfg
    }

    /// Fast tier: post-process the inner planner's directives with one
    /// AIMD decision per telemetry-covered committed flow. Returns the
    /// rewritten directive list.
    fn fast_tier(&mut self, ctx: &TickContext<'_>, inner_out: Vec<Directive>) -> Vec<Directive> {
        // Tenant-aggregate caps (hierarchical mode): a leaf's ceiling must
        // never exceed its tenant's committed aggregate — the bound the
        // nudge property test pins.
        let tenant_caps: std::collections::BTreeMap<(usize, usize), f64> =
            if self.inner.hierarchical() {
                planner::tenant_aggregates(self.inner.status_table())
                    .into_iter()
                    .map(|(a, v, s)| {
                        ((a, v), s * self.inner.planner_cfg().shaping_headroom)
                    })
                    .collect()
            } else {
                std::collections::BTreeMap::new()
            };
        // Decide per windowed flow; remember which flows the fast tier
        // took over so the inner planner's SetRate for them is dropped.
        let mut overridden: Vec<FlowId> = Vec::new();
        let mut nudges: Vec<Directive> = Vec::new();
        for &(flow, _) in ctx.windows {
            let Some(att) = ctx.obs.flow_attainment_ppm(flow) else { continue };
            let Some(row) = self.inner.status_table().get(flow) else { continue };
            if row.accel_name == "storage" || matches!(row.slo, Slo::BestEffort) {
                continue; // the SSD is its own authority; §6 handles BE
            }
            let Some((slo_rate, _mode)) = row.slo.required_rate() else { continue };
            let depth = ctx.obs.flow_queue_depth(flow).unwrap_or(0);
            let prev_depth = self.last_depth.insert(flow, depth).unwrap_or(0);
            let meeting = att >= 1_000_000u64.saturating_sub(self.cfg.deadband_ppm);
            let growing = depth > prev_depth;
            let backlogged = depth >= self.cfg.backlog_depth;
            if meeting && !backlogged {
                // Meeting, drained: the inner decay owns the rate again.
                self.commanded.remove(&flow);
                continue;
            }
            if !meeting && depth == 0 {
                // Violating with nothing queued: the flow is under-offered,
                // not under-shaped — no nudge can manufacture demand.
                self.commanded.remove(&flow);
                continue;
            }
            // The fast tier is the rate authority for this flow this tick —
            // whatever the static planner wanted is replaced. While it
            // holds authority, `commanded` (not the row, which the inner
            // tick may have just decayed) is the rate the hardware runs.
            overridden.push(flow);
            let headroom = self.inner.planner_cfg().shaping_headroom;
            let current = self
                .commanded
                .get(&flow)
                .copied()
                .or(row.shaped_rate)
                .unwrap_or(slo_rate * headroom);
            let floor = slo_rate; // the guarantee: never shape below contract
            let mut cap = slo_rate * self.cfg.max_ceiling;
            if let Some(&agg) = tenant_caps.get(&(row.accel, row.vm)) {
                cap = cap.min(agg);
            }
            let cap = cap.max(floor);
            let target = if !meeting && growing {
                // Queue growing under violation: the engine cannot keep up
                // — offering more only builds backlog. Back off toward the
                // guarantee (never below it, never above the tenant cap).
                (current * self.cfg.decrease_factor).max(floor).min(cap)
            } else {
                // Capacity is available and demand is queued — violating
                // with a stable/draining queue, or meeting with a backlog
                // (post-fault catch-up the static decay would strand).
                // Snap back to at least the guarantee, then ramp additively.
                (current.max(floor) + slo_rate * self.cfg.increase_step).min(cap)
            };
            if (target - current).abs() / current.max(1.0) > 0.01 {
                self.inner.note_shaped_rate(flow, target);
                self.commanded.insert(flow, target);
                nudges.push(Directive::set_rate(ctx.now, flow, target));
            } else {
                // Hold: the hardware stays at `current`, but the inner tick
                // may have decayed (or boosted) its row this tick and its
                // directive was filtered — write the held rate back so the
                // planner's picture matches the shaper it cannot see.
                self.inner.note_shaped_rate(flow, current);
                self.commanded.insert(flow, current);
            }
        }
        let mut out: Vec<Directive> = inner_out
            .into_iter()
            .filter(|d| match d.kind {
                DirectiveKind::SetRate { flow, .. } => !overridden.contains(&flow),
                _ => true,
            })
            .collect();
        out.extend(nudges);
        out
    }

    /// Slow tier: every `replan_every` ticks in hierarchical mode, re-plan
    /// per-(engine, tenant) envelopes from windowed usage. Guarantees are
    /// the committed sums (scaled down only if shaping headroom pushed
    /// their total past the admission budget); ceilings hand the engine's
    /// spare budget to the tenants that moved bytes recently.
    fn slow_tier(&mut self, ctx: &TickContext<'_>) -> Vec<Directive> {
        let mut out = Vec::new();
        let headroom = self.inner.planner_cfg().shaping_headroom;
        let aggregates = planner::tenant_aggregates(self.inner.status_table());
        // Group by engine, preserving the BTreeMap-derived order.
        let mut engines: Vec<usize> = aggregates.iter().map(|&(a, _, _)| a).collect();
        engines.dedup();
        let mut current: std::collections::BTreeMap<(usize, usize), (f64, f64)> =
            std::collections::BTreeMap::new();
        for engine in engines {
            let Some(budget) = self.inner.engine_budget_for(engine) else { continue };
            let tenants: Vec<(usize, f64)> = aggregates
                .iter()
                .filter(|&&(a, _, _)| a == engine)
                .map(|&(_, v, s)| (v, s * headroom))
                .collect();
            let guarantee_sum: f64 = tenants.iter().map(|&(_, g)| g).sum();
            // Safety floor: programmed guarantee sums never exceed the
            // true admission budget, even after the headroom multiplier.
            let scale = if guarantee_sum > budget { budget / guarantee_sum } else { 1.0 };
            let spare = (budget - guarantee_sum * scale).max(0.0);
            let usage: Vec<u64> = tenants
                .iter()
                .map(|&(v, _)| {
                    ctx.obs.tenant_bytes_delta(v, self.cfg.replan_every).unwrap_or(0)
                })
                .collect();
            let used_total: f64 = usage.iter().map(|&u| u as f64).sum();
            for (i, &(vm, g)) in tenants.iter().enumerate() {
                let guarantee = g * scale;
                // Usage-weighted share of the spare budget; equal shares
                // when the window saw no traffic at all.
                let share = if used_total > 0.0 {
                    usage[i] as f64 / used_total
                } else {
                    1.0 / tenants.len() as f64
                };
                let ceiling = (guarantee + spare * share).min(budget);
                current.insert((engine, vm), (guarantee, ceiling));
                let stale = match self.announced.get(&(engine, vm)) {
                    Some(&(pg, pc)) => {
                        (pg - guarantee).abs() > guarantee.abs().max(1.0) * 1e-9
                            || (pc - ceiling).abs() > ceiling.abs().max(1.0) * 1e-3
                    }
                    None => true,
                };
                if stale {
                    out.push(Directive::set_aggregate(ctx.now, engine, vm, guarantee, ceiling));
                    // Keep the inner diff quiet: record the *canonical*
                    // envelope it would compute, so it does not re-announce
                    // (and revert) the re-planned ceiling next tick.
                    self.inner.note_announced_aggregate(engine, vm, g, budget);
                }
            }
        }
        self.announced = current;
        out
    }
}

impl ControlPlane for AdaptiveControlPlane {
    fn register_flow(&mut self, req: &RegisterRequest) -> Result<Admitted, ApiError> {
        self.inner.register_flow(req)
    }

    fn update_slo(&mut self, flow: FlowId, slo: Slo) -> Result<Admitted, ApiError> {
        self.inner.update_slo(flow, slo)
    }

    fn deregister_flow(&mut self, flow: FlowId) -> Result<(), ApiError> {
        let r = self.inner.deregister_flow(flow);
        if r.is_ok() {
            self.last_depth.remove(&flow);
            self.commanded.remove(&flow);
        }
        r
    }

    fn query_status(&self, flow: FlowId) -> Option<FlowStatusView> {
        self.inner.query_status(flow)
    }

    fn set_profile_skew(&mut self, accel: &str, factor: f64) {
        self.inner.set_profile_skew(accel, factor);
    }

    fn tick(&mut self, ctx: &TickContext<'_>) -> Vec<Directive> {
        self.ticks += 1;
        let inner_out = self.inner.tick(ctx);
        let mut out = self.fast_tier(ctx, inner_out);
        if self.inner.hierarchical() && self.ticks % self.cfg.replan_every == 0 {
            out.extend(self.slow_tier(ctx));
        }
        out
    }

    fn needs_ticks(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "adaptive"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::AccelModel;
    use crate::coordinator::status::MeasuredWindow;
    use crate::coordinator::PlannerConfig;
    use crate::flow::{FlowKind, Path};
    use crate::obs::{ObsConfig, ObsPlane};
    use crate::pcie::fabric::FabricConfig;
    use crate::util::units::{MICROS, MILLIS};

    fn plane(hier: bool) -> AdaptiveControlPlane {
        let inner = ArcusControlPlane::from_models(
            &[AccelModel::ipsec_32g()],
            &FabricConfig::gen3_x8(),
            PlannerConfig::default(),
        )
        .with_hierarchy(hier);
        AdaptiveControlPlane::new(inner, AdaptiveConfig::default())
    }

    fn req(flow: FlowId, slo: Slo) -> RegisterRequest {
        RegisterRequest {
            flow,
            vm: flow,
            path: Path::FunctionCall,
            accel: 0,
            accel_name: "ipsec".into(),
            kind: FlowKind::Accel,
            slo,
            size_hint: 1500,
        }
    }

    /// Fresh obs plane for `n_flows` flows, one tenant each, one engine.
    /// A 100 µs window against a 10 Gbps SLO meets at exactly 125_000
    /// bytes, so 100_000-byte samples ≈ 800_000 ppm (violating).
    fn obs_plane(n_flows: usize) -> ObsPlane {
        let homes: Vec<(usize, usize)> = (0..n_flows).map(|f| (f, 0)).collect();
        let mut obs = ObsPlane::new(
            ObsConfig {
                control_period: 100 * MICROS,
                duration: 10 * MILLIS,
                retention: 64,
                sample_every: 1,
            },
            &homes,
            n_flows,
            1,
            None,
        );
        for f in 0..n_flows {
            obs.set_flow_slo(f, Slo::gbps(10.0));
        }
        obs
    }

    /// Push one control-tick sample for every flow: `window_bytes` moved
    /// over the 100 µs window at queue depth `depth`.
    fn push_sample(obs: &mut ObsPlane, tick: u64, n_flows: usize, window_bytes: u64, depth: usize) {
        for f in 0..n_flows {
            obs.on_complete(f, (tick + 1) * 100 * MICROS, 1_000, window_bytes);
            obs.on_control_sample(
                tick,
                f,
                100 * MICROS,
                window_bytes,
                1,
                Some(1_000),
                depth,
                0,
            );
        }
        obs.on_tick_done(tick);
    }

    #[test]
    fn config_validates_ranges() {
        assert!(AdaptiveConfig::default().validate().is_ok());
        let bad = AdaptiveConfig { decrease_factor: 1.5, ..AdaptiveConfig::default() };
        assert!(bad.validate().is_err());
        let bad = AdaptiveConfig { replan_every: 0, ..AdaptiveConfig::default() };
        assert!(bad.validate().is_err());
        let bad = AdaptiveConfig { increase_step: 0.0, ..AdaptiveConfig::default() };
        assert!(bad.validate().is_err());
        let bad = AdaptiveConfig { max_ceiling: 0.5, ..AdaptiveConfig::default() };
        assert!(bad.validate().is_err());
        let bad = AdaptiveConfig { deadband_ppm: 2_000_000, ..AdaptiveConfig::default() };
        assert!(bad.validate().is_err());
        let bad = AdaptiveConfig { backlog_depth: 0, ..AdaptiveConfig::default() };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn delegates_lifecycle_to_inner() {
        let mut cp = plane(false);
        cp.register_flow(&req(0, Slo::gbps(10.0))).unwrap();
        assert_eq!(cp.name(), "adaptive");
        assert!(cp.needs_ticks());
        assert!(cp.query_status(0).is_some());
        assert!(cp.update_slo(0, Slo::gbps(12.0)).is_ok());
        cp.deregister_flow(0).unwrap();
        assert!(cp.query_status(0).is_none());
        assert_eq!(
            cp.deregister_flow(0).unwrap_err(),
            ApiError::UnknownFlow { flow: 0 }
        );
    }

    #[test]
    fn without_telemetry_behaves_like_inner() {
        // No obs view attached → the fast tier has nothing to key on and
        // the wrapper must be a pass-through of the static planner.
        let mut cp = plane(false);
        cp.register_flow(&req(0, Slo::gbps(10.0))).unwrap();
        let w = MeasuredWindow { span: MILLIS, bytes: 1_000_000, ops: 667, p99_latency: None };
        let windows = [(0, w)];
        let mut last = Vec::new();
        for _ in 0..3 {
            last = cp.tick(&TickContext::new(0, &windows));
        }
        // The static planner boosts the violating flow; nothing filtered.
        let boosted = |d: &Directive| {
            matches!(d.kind, DirectiveKind::SetRate { flow: 0, rate } if rate > 10e9 / 8.0)
        };
        assert!(last.iter().any(boosted), "{last:?}");
    }

    #[test]
    fn growing_queue_under_violation_backs_off_to_guarantee() {
        let mut cp = plane(false);
        cp.register_flow(&req(0, Slo::gbps(10.0))).unwrap();
        let slo_rate = 10e9 / 8.0;
        // 8 of 10 Gbps attained, queue growing every tick: MD must land on
        // the guarantee floor and never below it.
        let mut obs = obs_plane(1);
        let w = MeasuredWindow { span: MILLIS, bytes: 1_000_000, ops: 667, p99_latency: None };
        let windows = [(0, w)];
        for t in 0..8u64 {
            push_sample(&mut obs, t, 1, 100_000, (100 + t * 50) as usize);
            let ds = cp.tick(&TickContext::new(0, &windows).with_obs(&obs));
            for d in &ds {
                if let DirectiveKind::SetRate { flow: 0, rate } = d.kind {
                    assert!(
                        rate >= slo_rate * 0.999,
                        "nudged below guarantee: {rate:.3e}"
                    );
                    assert!(rate <= slo_rate * 1.02, "MD should clamp, got {rate:.3e}");
                }
            }
        }
        let shaped = cp.query_status(0).unwrap().shaped_rate.unwrap();
        assert!(
            (shaped - slo_rate).abs() / slo_rate < 0.02,
            "expected clamp at guarantee, got {shaped:.3e}"
        );
    }

    #[test]
    fn draining_queue_under_violation_ramps_toward_ceiling() {
        let mut cp = plane(false);
        cp.register_flow(&req(0, Slo::gbps(10.0))).unwrap();
        let slo_rate = 10e9 / 8.0;
        // Violating but queue draining (post-fault recovery): AI must ramp
        // the rate, bounded by max_ceiling × SLO.
        let mut obs = obs_plane(1);
        let w = MeasuredWindow { span: MILLIS, bytes: 1_000_000, ops: 667, p99_latency: None };
        let windows = [(0, w)];
        for t in 0..20u64 {
            push_sample(&mut obs, t, 1, 100_000, (1000 - t * 40) as usize);
            cp.tick(&TickContext::new(0, &windows).with_obs(&obs));
        }
        let shaped = cp.query_status(0).unwrap().shaped_rate.unwrap();
        assert!(shaped > slo_rate * 1.05, "expected AI ramp, got {shaped:.3e}");
        assert!(
            shaped <= slo_rate * cp.adaptive_cfg().max_ceiling * 1.001,
            "ceiling breached: {shaped:.3e}"
        );
    }

    #[test]
    fn meeting_flow_with_backlog_gets_catch_up_ramp() {
        // A flow meeting its SLO but with a deep standing queue (e.g. the
        // backlog a fault left behind): the static decay would park it at
        // ~SLO; the fast tier must instead ramp it toward the ceiling so
        // the backlog drains, then release it once the queue is short.
        let mut cp = plane(false);
        cp.register_flow(&req(0, Slo::gbps(10.0))).unwrap();
        let slo_rate = 10e9 / 8.0;
        let mut obs = obs_plane(1);
        let w = MeasuredWindow { span: MILLIS, bytes: 1_700_000, ops: 1133, p99_latency: None };
        let windows = [(0, w)];
        for t in 0..20u64 {
            // 130_000 bytes / 100 µs ≈ 1.04e6 ppm (meeting); depth 500
            // stays far above backlog_depth.
            push_sample(&mut obs, t, 1, 130_000, 500);
            cp.tick(&TickContext::new(0, &windows).with_obs(&obs));
        }
        let shaped = cp.query_status(0).unwrap().shaped_rate.unwrap();
        assert!(shaped > slo_rate * 1.05, "expected catch-up ramp, got {shaped:.3e}");
        assert!(
            shaped <= slo_rate * cp.adaptive_cfg().max_ceiling * 1.001,
            "ceiling breached: {shaped:.3e}"
        );
        // Queue drains below the backlog threshold: the fast tier releases
        // the flow and the inner decay walks the rate back toward the SLO.
        for t in 20..40u64 {
            push_sample(&mut obs, t, 1, 130_000, 4);
            cp.tick(&TickContext::new(0, &windows).with_obs(&obs));
        }
        let released = cp.query_status(0).unwrap().shaped_rate.unwrap();
        assert!(
            released < shaped,
            "inner decay should reclaim the boost: {released:.3e} !< {shaped:.3e}"
        );
    }

    #[test]
    fn slow_tier_replans_aggregates_within_budget() {
        let mut cp = plane(true);
        cp.register_flow(&req(0, Slo::gbps(8.0))).unwrap();
        let mut r1 = req(1, Slo::gbps(8.0));
        r1.vm = 1;
        cp.register_flow(&r1).unwrap();
        let budget = cp.inner().engine_budget_for(0).unwrap();
        let mut obs = obs_plane(2);
        let w = MeasuredWindow { span: MILLIS, bytes: 1_500_000, ops: 1000, p99_latency: None };
        let windows = [(0, w), (1, w)];
        let mut aggs = Vec::new();
        for t in 0..cp.adaptive_cfg().replan_every {
            push_sample(&mut obs, t, 2, 130_000, 10);
            for d in cp.tick(&TickContext::new(0, &windows).with_obs(&obs)) {
                if let DirectiveKind::SetAggregate { engine, tenant, guarantee, ceiling } =
                    d.kind
                {
                    aggs.push((engine, tenant, guarantee, ceiling));
                }
            }
        }
        // The replan emitted one envelope per tenant, guarantees summing
        // under the admission budget and ceilings never exceeding it.
        let replanned: Vec<_> = aggs.iter().filter(|a| a.3 <= budget * 1.001).collect();
        assert!(replanned.len() >= 2, "expected slow-tier envelopes: {aggs:?}");
        let gsum: f64 = replanned.iter().map(|a| a.2).sum();
        assert!(gsum <= budget * 1.01, "guarantee sum {gsum:.3e} > budget {budget:.3e}");
    }
}
