//! Baseline control planes (§5.1): the management architectures Arcus is
//! compared against, behind the same [`ControlPlane`] trait.
//!
//! - [`NoOpControlPlane`] — Host_no_TS / Bypassed_PANIC: every registration
//!   is admitted unshaped, nothing is ever reshaped. SLO "management" is
//!   whatever the interface's arbiter happens to do.
//! - [`StaticRateControlPlane`] — Host_TS_*: software rate limiting at the
//!   SLO's average rate, configured once at registration ("the average
//!   ingress rate can be rate limited on the host"); no heterogeneity or
//!   contention awareness, no reshaping, renegotiations blindly accepted.

use crate::coordinator::status::SloState;
use crate::flow::{FlowId, Slo};

use super::control::{
    Admitted, ApiError, ControlPlane, Directive, FlowStatusView, RegisterRequest, ShaperProgram,
    TickContext,
};

/// Minimal registry shared by the baseline implementations.
#[derive(Debug, Default)]
struct Registry {
    rows: Vec<RegisterRequest>,
}

impl Registry {
    fn get(&self, flow: FlowId) -> Option<&RegisterRequest> {
        self.rows.iter().find(|r| r.flow == flow)
    }

    fn insert(&mut self, req: &RegisterRequest) -> Result<(), ApiError> {
        if self.get(req.flow).is_some() {
            return Err(ApiError::AlreadyRegistered { flow: req.flow });
        }
        self.rows.push(req.clone());
        Ok(())
    }

    fn remove(&mut self, flow: FlowId) -> Result<(), ApiError> {
        match self.rows.iter().position(|r| r.flow == flow) {
            Some(i) => {
                self.rows.remove(i);
                Ok(())
            }
            None => Err(ApiError::UnknownFlow { flow }),
        }
    }

    fn view(&self, flow: FlowId, shaped_rate: Option<f64>) -> Option<FlowStatusView> {
        self.get(flow).map(|r| FlowStatusView {
            flow: r.flow,
            vm: r.vm,
            path: r.path,
            accel: r.accel,
            slo: r.slo,
            shaped_rate,
            state: SloState::Warmup,
            violations: 0,
            reconfigs: 0,
        })
    }
}

/// Admit-everything, shape-nothing (Host_no_TS / Bypassed_PANIC).
#[derive(Debug, Default)]
pub struct NoOpControlPlane {
    registry: Registry,
}

impl NoOpControlPlane {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }
}

impl ControlPlane for NoOpControlPlane {
    fn register_flow(&mut self, req: &RegisterRequest) -> Result<Admitted, ApiError> {
        self.registry.insert(req)?;
        Ok(Admitted { committed_rate: None, program: ShaperProgram::Unshaped })
    }

    fn update_slo(&mut self, flow: FlowId, slo: Slo) -> Result<Admitted, ApiError> {
        match self.registry.rows.iter_mut().find(|r| r.flow == flow) {
            Some(r) => {
                r.slo = slo;
                Ok(Admitted { committed_rate: None, program: ShaperProgram::Unshaped })
            }
            None => Err(ApiError::UnknownFlow { flow }),
        }
    }

    fn deregister_flow(&mut self, flow: FlowId) -> Result<(), ApiError> {
        self.registry.remove(flow)
    }

    fn query_status(&self, flow: FlowId) -> Option<FlowStatusView> {
        self.registry.view(flow, None)
    }

    fn tick(&mut self, _ctx: &TickContext<'_>) -> Vec<Directive> {
        Vec::new()
    }

    fn needs_ticks(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str {
        "noop"
    }
}

/// Static software shaping at the SLO average (Host_TS_Reflex /
/// Host_TS_Firecracker).
#[derive(Debug, Default)]
pub struct StaticRateControlPlane {
    registry: Registry,
}

impl StaticRateControlPlane {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    fn program_for(slo: &Slo) -> ShaperProgram {
        match slo.required_rate() {
            Some((rate, mode)) => ShaperProgram::Software { rate, mode },
            None => ShaperProgram::Unshaped,
        }
    }
}

impl ControlPlane for StaticRateControlPlane {
    fn register_flow(&mut self, req: &RegisterRequest) -> Result<Admitted, ApiError> {
        self.registry.insert(req)?;
        Ok(Admitted {
            committed_rate: req.slo.required_rate().map(|(r, _)| r),
            program: Self::program_for(&req.slo),
        })
    }

    fn update_slo(&mut self, flow: FlowId, slo: Slo) -> Result<Admitted, ApiError> {
        // No capacity planning: the host limiter is blindly reprogrammed.
        match self.registry.rows.iter_mut().find(|r| r.flow == flow) {
            Some(r) => {
                r.slo = slo;
                Ok(Admitted {
                    committed_rate: slo.required_rate().map(|(rate, _)| rate),
                    program: Self::program_for(&slo),
                })
            }
            None => Err(ApiError::UnknownFlow { flow }),
        }
    }

    fn deregister_flow(&mut self, flow: FlowId) -> Result<(), ApiError> {
        self.registry.remove(flow)
    }

    fn query_status(&self, flow: FlowId) -> Option<FlowStatusView> {
        let rate = self
            .registry
            .get(flow)
            .and_then(|r| r.slo.required_rate())
            .map(|(rate, _)| rate);
        self.registry.view(flow, rate)
    }

    fn tick(&mut self, _ctx: &TickContext<'_>) -> Vec<Directive> {
        Vec::new()
    }

    fn needs_ticks(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str {
        "static_rate"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::{FlowKind, Path};

    fn req(flow: FlowId, slo: Slo) -> RegisterRequest {
        RegisterRequest {
            flow,
            vm: flow,
            path: Path::FunctionCall,
            accel: 0,
            accel_name: "ipsec".into(),
            kind: FlowKind::Accel,
            slo,
            size_hint: 1500,
        }
    }

    #[test]
    fn noop_admits_everything_unshaped() {
        let mut cp = NoOpControlPlane::new();
        for i in 0..32 {
            let a = cp.register_flow(&req(i, Slo::gbps(100.0))).unwrap();
            assert_eq!(a.program, ShaperProgram::Unshaped);
            assert!(a.committed_rate.is_none());
        }
        assert!(cp.tick(&TickContext::new(0, &[])).is_empty());
        assert!(!cp.needs_ticks());
        assert!(cp.query_status(3).is_some());
        cp.deregister_flow(3).unwrap();
        assert!(cp.query_status(3).is_none());
        assert!(cp.register_flow(&req(0, Slo::gbps(1.0))).is_err());
    }

    #[test]
    fn static_rate_programs_software_shaper_at_slo_average() {
        let mut cp = StaticRateControlPlane::new();
        let a = cp.register_flow(&req(0, Slo::gbps(10.0))).unwrap();
        match a.program {
            ShaperProgram::Software { rate, .. } => {
                assert!((rate - 1.25e9).abs() < 1.0);
            }
            other => panic!("expected software program, got {other:?}"),
        }
        // Best-effort flows stay unshaped even here.
        let b = cp.register_flow(&req(1, Slo::BestEffort)).unwrap();
        assert_eq!(b.program, ShaperProgram::Unshaped);
        // Renegotiation reprograms blindly (no capacity planning).
        let c = cp.update_slo(0, Slo::gbps(50.0)).unwrap();
        assert!(matches!(c.program, ShaperProgram::Software { .. }));
        assert_eq!(cp.query_status(0).unwrap().slo, Slo::gbps(50.0));
    }
}
