//! The control-plane API: typed requests, responses, errors, and the
//! [`ControlPlane`] trait.
//!
//! Arcus's core contribution is an SLO-aware *protocol* between tenants and
//! the accelerator runtime (§4.3): a flow registers with an SLO and is
//! admitted or rejected by capacity planning; a registered flow may
//! renegotiate its SLO; the runtime watches hardware counters and reshapes
//! violating flows; a departing flow releases its committed capacity. This
//! module types that protocol so the dataplane (the DES engine today, the
//! wall-clock serving runtime and any multi-node frontend tomorrow) talks to
//! the coordinator exclusively through it.
//!
//! Division of labour: the control plane *decides* (admission, shaping
//! rates, path moves) and the dataplane *applies* (programs token-bucket
//! registers, re-routes DMA). Decisions come back as a [`ShaperProgram`] on
//! the synchronous calls and as [`Directive`]s from [`ControlPlane::tick`];
//! the dataplane applies directives after the measured ~10 µs MMIO
//! reconfiguration latency (§5.3.1), never stalling active flows.

use crate::coordinator::planner::RejectReason;
use crate::coordinator::status::{MeasuredWindow, SloState};
use crate::flow::{FlowId, FlowKind, Path, Slo};
use crate::obs::{ObsPlane, SeriesRing, GAUGE_NONE};
use crate::shaping::{ShapeMode, TokenBucketParams};
use crate::util::units::Time;

/// What a tenant submits when registering a flow (the PerFlowStatusTable
/// context of §4.3: VM, path, accelerator, SLO, and the message-size hint
/// that keys the Capacity(t, X, N) profile lookup).
#[derive(Debug, Clone)]
pub struct RegisterRequest {
    /// Caller-chosen flow id (unique among registered flows).
    pub flow: FlowId,
    /// Tenant VM the flow belongs to.
    pub vm: usize,
    /// Invocation path (function call / inline NIC / P2P).
    pub path: Path,
    /// Accelerator index in the system's device list.
    pub accel: usize,
    /// Accelerator model name (profile-table key; "storage" for NVMe flows).
    pub accel_name: String,
    /// Accelerator vs storage-read vs storage-write flow.
    pub kind: FlowKind,
    /// The service-level objective the tenant asks to commit.
    pub slo: Slo,
    /// Message size this flow predominantly uses (profiling context key).
    pub size_hint: u64,
}

/// A shaper configuration the dataplane must program at the interface.
#[derive(Debug, Clone, PartialEq)]
pub enum ShaperProgram {
    /// Leave the flow unshaped (latency-critical flows, unmanaged modes).
    Unshaped,
    /// Program a hardware token bucket: install `params`, then retune the
    /// registers to `rate` units/sec (the control plane pre-applies its
    /// shaping headroom so the measured rate lands ON the SLO).
    TokenBucket {
        params: TokenBucketParams,
        rate: f64,
        mode: ShapeMode,
    },
    /// Program a host-software rate limiter (the Host_TS_* baselines).
    Software { rate: f64, mode: ShapeMode },
    /// Hang the flow off the hierarchical shaper tree
    /// ([`crate::shaping::ShaperTree`]) as a *paced leaf* under its
    /// tenant's aggregate node on the flow's engine — the scalable form of
    /// shaping (§5): no per-flow hardware bucket, release driven by the
    /// tree's deficit-round-robin pacing pass. The install also carries
    /// the absolute tenant-aggregate and engine-root envelopes as of this
    /// decision, so one program upserts every level it hangs from.
    Hierarchy {
        /// Tenant aggregate (VM) this leaf hangs off.
        tenant: usize,
        /// Leaf assured rate (units/sec).
        guarantee: f64,
        /// Leaf borrowing cap (units/sec).
        ceiling: f64,
        /// Tenant aggregate assured rate, absolute (units/sec).
        tenant_guarantee: f64,
        /// Tenant aggregate borrowing cap, absolute (units/sec).
        tenant_ceiling: f64,
        /// Engine-root ceiling (units/sec; the admission budget).
        engine_ceiling: f64,
        /// Cost units (bytes vs messages).
        mode: ShapeMode,
    },
}

/// Successful registration / renegotiation outcome.
#[derive(Debug, Clone)]
pub struct Admitted {
    /// Shaping rate (units/sec) now committed in the capacity plan, if the
    /// SLO carries one.
    pub committed_rate: Option<f64>,
    /// Shaper program the dataplane must install for this flow.
    pub program: ShaperProgram,
}

/// Typed control-plane failures.
///
/// Rejections are *structured*: [`ApiError::Rejection`] carries a typed
/// [`RejectReason`] (no string matching required downstream) plus an
/// optional `retry_after` hint — admission backpressure a closed-loop
/// caller (the adaptive plane, a tenant SDK) can consume to schedule a
/// retry instead of giving up.
#[derive(Debug, Clone, PartialEq)]
pub enum ApiError {
    /// Capacity planning refused the SLO (Algorithm 1 lines 7–10).
    Rejection {
        /// Why admission control said no (typed; `Display` is human text).
        reason: RejectReason,
        /// When a retry could plausibly succeed: `Some(t)` for transient
        /// rejections (capacity may free up after the next control
        /// period), `None` for structural ones (no profile for the
        /// context — retrying changes nothing).
        retry_after: Option<Time>,
    },
    /// The flow id is already registered.
    AlreadyRegistered { flow: FlowId },
    /// The flow id is not registered.
    UnknownFlow { flow: FlowId },
}

impl ApiError {
    /// Shorthand for a rejection with no retry hint.
    pub fn rejected(reason: RejectReason) -> Self {
        ApiError::Rejection { reason, retry_after: None }
    }
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ApiError::Rejection { reason, retry_after } => {
                write!(f, "admission rejected: {reason}")?;
                if let Some(t) = retry_after {
                    write!(f, " (retry after {t} ps)")?;
                }
                Ok(())
            }
            ApiError::AlreadyRegistered { flow } => {
                write!(f, "flow {flow} is already registered")
            }
            ApiError::UnknownFlow { flow } => write!(f, "flow {flow} is not registered"),
        }
    }
}

impl std::error::Error for ApiError {}

/// An asynchronous reconfiguration the control plane asks the dataplane to
/// apply (MMIO register writes / path re-routing; the dataplane models the
/// ~10 µs PCIe round-trip latency before the change takes effect).
///
/// Every directive is stamped with the virtual time it was *issued* at, so
/// the dataplane can measure directive-propagation lag (apply time minus
/// issue time) — the metric a future fleet/xDS distribution layer will be
/// judged on.
#[derive(Debug, Clone, PartialEq)]
pub struct Directive {
    /// Virtual time at which the control plane issued this directive.
    pub issued_at: Time,
    /// The reconfiguration to apply.
    pub kind: DirectiveKind,
}

impl Directive {
    /// Reprogram a flow's shaper to `rate` units/sec.
    pub fn set_rate(issued_at: Time, flow: FlowId, rate: f64) -> Self {
        Directive { issued_at, kind: DirectiveKind::SetRate { flow, rate } }
    }

    /// Re-route a flow to path `to`.
    pub fn switch_path(issued_at: Time, flow: FlowId, to: Path) -> Self {
        Directive { issued_at, kind: DirectiveKind::SwitchPath { flow, to } }
    }

    /// (Re)program a tenant aggregate envelope on an engine's shaper tree.
    pub fn set_aggregate(
        issued_at: Time,
        engine: usize,
        tenant: usize,
        guarantee: f64,
        ceiling: f64,
    ) -> Self {
        Directive {
            issued_at,
            kind: DirectiveKind::SetAggregate { engine, tenant, guarantee, ceiling },
        }
    }

    /// Install (or replace) a flow's full shaper program.
    pub fn install_program(issued_at: Time, flow: FlowId, program: ShaperProgram) -> Self {
        Directive { issued_at, kind: DirectiveKind::InstallProgram { flow, program } }
    }

    /// The flow this directive targets, when it targets exactly one.
    pub fn flow(&self) -> Option<FlowId> {
        match self.kind {
            DirectiveKind::SetRate { flow, .. }
            | DirectiveKind::SwitchPath { flow, .. }
            | DirectiveKind::InstallProgram { flow, .. } => Some(flow),
            DirectiveKind::SetAggregate { .. } => None,
        }
    }
}

/// The reconfiguration payload of a [`Directive`].
#[derive(Debug, Clone, PartialEq)]
pub enum DirectiveKind {
    /// Reprogram a flow's shaper to a new rate (units/sec). On a tree-
    /// paced leaf this caps the leaf's ceiling at `rate` — the flat
    /// register semantics ("the flow cannot exceed `rate`") preserved.
    SetRate { flow: FlowId, rate: f64 },
    /// Re-route a flow to a less-contended invocation path.
    SwitchPath { flow: FlowId, to: Path },
    /// Tree-install: (re)program a tenant aggregate node on an engine's
    /// shaper tree with an absolute `(guarantee, ceiling)` envelope in
    /// units/sec. Emitted by the hierarchical planner whenever a tenant's
    /// committed sum changes (arrival, departure, renegotiation,
    /// over-commit rebalance).
    SetAggregate {
        /// Engine (accelerator index) whose tree carries the node.
        engine: usize,
        /// Tenant aggregate (VM) to reprogram.
        tenant: usize,
        /// Assured rate of the aggregate (units/sec).
        guarantee: f64,
        /// Borrowing cap of the aggregate (units/sec).
        ceiling: f64,
    },
    /// Install (or replace) a flow's entire shaper program — the
    /// renegotiation path: a successful `update_slo` returns the new
    /// program synchronously, and the dataplane applies it through the
    /// same directive pipeline (and the same 10 µs rule) as every other
    /// reconfiguration.
    InstallProgram {
        /// Flow whose shaper is replaced.
        flow: FlowId,
        /// The program to install.
        program: ShaperProgram,
    },
}

/// Everything a control plane may consult during one tick: the virtual
/// clock, the dataplane's fresh per-flow hardware counters, and a
/// read-only window onto the observability plane's historical series.
///
/// This replaces the PR-2-era `tick(now, &[(FlowId, MeasuredWindow)])`
/// signature: the raw windows-slice could not carry per-era
/// attainment/p99/queue-depth telemetry, so feedback controllers had
/// nothing to close a loop on. `TickContext` is a plain borrow bundle —
/// building one allocates nothing, and a context without an obs view
/// ([`TickContext::new`]) is valid everywhere (the static planes ignore
/// telemetry entirely).
pub struct TickContext<'a> {
    /// Virtual time of this control tick.
    pub now: Time,
    /// One fresh [`MeasuredWindow`] per registered flow.
    pub windows: &'a [(FlowId, MeasuredWindow)],
    /// Read-only view over the observability plane's series (may be
    /// empty: unit tests and obs-disabled runs pass no plane).
    pub obs: ObsView<'a>,
}

impl<'a> TickContext<'a> {
    /// A context with no observability view (unit tests, obs-off runs).
    pub fn new(now: Time, windows: &'a [(FlowId, MeasuredWindow)]) -> Self {
        TickContext { now, windows, obs: ObsView::empty() }
    }

    /// Attach a read-only observability view.
    pub fn with_obs(mut self, plane: &'a ObsPlane) -> Self {
        self.obs = ObsView::of(plane);
        self
    }
}

/// Read-only telemetry window handed to control planes each tick.
///
/// Wraps the engine's [`ObsPlane`] (which samples every control tick on
/// the DES queue, so everything here is deterministic) and exposes only
/// *latest-sample* gauges and *windowed counter deltas* — the accessors a
/// feedback controller needs, without granting mutable or structural
/// access to the plane. All accessors are total: a missing flow, an
/// empty series, or a [`GAUGE_NONE`] sentinel all come back as `None`.
#[derive(Clone, Copy)]
pub struct ObsView<'a> {
    plane: Option<&'a ObsPlane>,
}

impl<'a> ObsView<'a> {
    /// A view over nothing: every accessor returns `None`.
    pub fn empty() -> Self {
        ObsView { plane: None }
    }

    /// A view over a live observability plane.
    pub fn of(plane: &'a ObsPlane) -> Self {
        ObsView { plane: Some(plane) }
    }

    /// Is there a plane behind this view at all?
    pub fn is_attached(&self) -> bool {
        self.plane.is_some()
    }

    fn gauge(ring: &SeriesRing) -> Option<u64> {
        ring.latest().filter(|&v| v != GAUGE_NONE)
    }

    /// Latest sampled SLO attainment for `flow`, in parts-per-million
    /// (1_000_000 = exactly meeting the SLO).
    pub fn flow_attainment_ppm(&self, flow: FlowId) -> Option<u64> {
        let s = self.plane?.flow_series(flow)?;
        Self::gauge(&s.attainment_ppm)
    }

    /// Latest sampled windowed p99 latency for `flow`, in picoseconds.
    pub fn flow_p99_ps(&self, flow: FlowId) -> Option<u64> {
        let s = self.plane?.flow_series(flow)?;
        Self::gauge(&s.p99_ps)
    }

    /// Latest sampled dataplane queue depth for `flow` (queued + inflight).
    pub fn flow_queue_depth(&self, flow: FlowId) -> Option<u64> {
        let s = self.plane?.flow_series(flow)?;
        Self::gauge(&s.queue_depth)
    }

    /// Cumulative reconfiguration directives applied to `flow` as of the
    /// latest sample.
    pub fn flow_directives(&self, flow: FlowId) -> Option<u64> {
        let s = self.plane?.flow_series(flow)?;
        s.directives.latest()
    }

    /// Bytes tenant `vm` moved over (roughly) the last `ticks_back`
    /// control ticks: latest cumulative sample minus the sample
    /// `ticks_back` ticks earlier (clamped to the oldest retained
    /// sample). `None` until the tenant has at least one sample.
    pub fn tenant_bytes_delta(&self, vm: usize, ticks_back: u64) -> Option<u64> {
        let t = self.plane?.tenant(vm)?;
        Self::counter_delta(&t.bytes_series, ticks_back)
    }

    /// Bytes engine `engine` moved over (roughly) the last `ticks_back`
    /// control ticks (same windowing rules as [`Self::tenant_bytes_delta`]).
    pub fn engine_bytes_delta(&self, engine: usize, ticks_back: u64) -> Option<u64> {
        let e = self.plane?.engine(engine)?;
        Self::counter_delta(&e.bytes_series, ticks_back)
    }

    fn counter_delta(ring: &SeriesRing, ticks_back: u64) -> Option<u64> {
        let newest = ring.next_tick().checked_sub(1)?;
        let latest = ring.get(newest)?;
        let base_tick = newest.saturating_sub(ticks_back).max(ring.first_tick());
        let base = if base_tick == newest { 0 } else { ring.get(base_tick).unwrap_or(0) };
        Some(latest.saturating_sub(base))
    }
}

/// Point-in-time view of one registered flow, for `query_status`.
#[derive(Debug, Clone)]
pub struct FlowStatusView {
    /// Flow id.
    pub flow: FlowId,
    /// Tenant VM.
    pub vm: usize,
    /// Current invocation path (may change via `SwitchPath`).
    pub path: Path,
    /// Accelerator index.
    pub accel: usize,
    /// SLO currently in force (tracks renegotiations).
    pub slo: Slo,
    /// Shaping rate currently programmed (units/sec), if shaped.
    pub shaped_rate: Option<f64>,
    /// Meeting / violating / warmup standing of the last window.
    pub state: SloState,
    /// Consecutive violating windows.
    pub violations: u32,
    /// Reconfigurations issued for this flow.
    pub reconfigs: u32,
}

/// The flow-lifecycle protocol between tenants/dataplane and the SLO
/// runtime.
///
/// Implementations: [`crate::api::ArcusControlPlane`] (profile tables +
/// Algorithm 1), [`crate::api::StaticRateControlPlane`] (Host_TS software
/// shaping at the SLO average), and [`crate::api::NoOpControlPlane`]
/// (unmanaged baselines). The dataplane owns the hardware (shapers, DMA
/// routing) and must not reach past this trait into coordinator internals.
///
/// `Send` is a supertrait so a per-host `World` (which boxes its plane) can
/// advance on a fleet worker thread between interchange barriers.
pub trait ControlPlane: Send {
    /// Register a flow: admission control plus initial shaper programming.
    fn register_flow(&mut self, req: &RegisterRequest) -> Result<Admitted, ApiError>;

    /// Renegotiate a registered flow's SLO. On rejection the old SLO (and
    /// its shaper program) stays in force.
    fn update_slo(&mut self, flow: FlowId, slo: Slo) -> Result<Admitted, ApiError>;

    /// Deregister a flow, releasing its committed capacity for later
    /// arrivals or renegotiations to claim.
    fn deregister_flow(&mut self, flow: FlowId) -> Result<(), ApiError>;

    /// Current status of one registered flow (None when unknown).
    fn query_status(&self, flow: FlowId) -> Option<FlowStatusView>;

    /// One control-loop tick: ingest the dataplane's measured hardware
    /// counters (and, when attached, the observability plane's series)
    /// and emit reconfiguration directives (Algorithm 1 lines 2–6). The
    /// [`TickContext`] carries the virtual clock, one fresh
    /// [`MeasuredWindow`] per registered flow, and a read-only
    /// [`ObsView`]; every directive must be stamped `issued_at =
    /// ctx.now`.
    fn tick(&mut self, ctx: &TickContext<'_>) -> Vec<Directive>;

    /// Does this control plane run a periodic tick at all? (The unmanaged
    /// and statically-shaped baselines do not.)
    fn needs_ticks(&self) -> bool;

    /// Fault-injection / re-profiling hook: scale this plane's *belief*
    /// about `accel`'s capacity by `factor` (the hardware is untouched;
    /// only the table lies). `factor == 1.0` restores the true table.
    /// Default: ignored — the baseline planes hold no profile state.
    fn set_profile_skew(&mut self, _accel: &str, _factor: f64) {}

    /// Implementation name, for reports.
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn api_error_display_is_informative() {
        let e = ApiError::Rejection {
            reason: RejectReason::CapacityExceeded {
                budget: 1e9,
                committed: 9e8,
                requested: 2e9,
            },
            retry_after: None,
        };
        assert!(e.to_string().contains("admission rejected"));
        assert!(e.to_string().contains("capacity"));
        let hinted = ApiError::Rejection {
            reason: RejectReason::CapacityExceeded {
                budget: 1e9,
                committed: 9e8,
                requested: 2e9,
            },
            retry_after: Some(100_000_000),
        };
        assert!(hinted.to_string().contains("retry after 100000000 ps"));
        assert_eq!(
            ApiError::UnknownFlow { flow: 7 }.to_string(),
            "flow 7 is not registered"
        );
        assert_eq!(
            ApiError::AlreadyRegistered { flow: 3 }.to_string(),
            "flow 3 is already registered"
        );
    }

    #[test]
    fn directive_constructors_stamp_issue_time() {
        let d = Directive::set_rate(42, 3, 1.5e9);
        assert_eq!(d.issued_at, 42);
        assert_eq!(d.flow(), Some(3));
        assert!(matches!(d.kind, DirectiveKind::SetRate { flow: 3, .. }));
        let agg = Directive::set_aggregate(7, 0, 1, 1.0, 2.0);
        assert_eq!(agg.flow(), None);
    }

    #[test]
    fn empty_obs_view_is_total() {
        let view = ObsView::empty();
        assert!(!view.is_attached());
        assert_eq!(view.flow_attainment_ppm(0), None);
        assert_eq!(view.flow_p99_ps(0), None);
        assert_eq!(view.flow_queue_depth(0), None);
        assert_eq!(view.tenant_bytes_delta(0, 8), None);
        assert_eq!(view.engine_bytes_delta(0, 8), None);
    }
}
