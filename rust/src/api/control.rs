//! The control-plane API: typed requests, responses, errors, and the
//! [`ControlPlane`] trait.
//!
//! Arcus's core contribution is an SLO-aware *protocol* between tenants and
//! the accelerator runtime (§4.3): a flow registers with an SLO and is
//! admitted or rejected by capacity planning; a registered flow may
//! renegotiate its SLO; the runtime watches hardware counters and reshapes
//! violating flows; a departing flow releases its committed capacity. This
//! module types that protocol so the dataplane (the DES engine today, the
//! wall-clock serving runtime and any multi-node frontend tomorrow) talks to
//! the coordinator exclusively through it.
//!
//! Division of labour: the control plane *decides* (admission, shaping
//! rates, path moves) and the dataplane *applies* (programs token-bucket
//! registers, re-routes DMA). Decisions come back as a [`ShaperProgram`] on
//! the synchronous calls and as [`Directive`]s from [`ControlPlane::tick`];
//! the dataplane applies directives after the measured ~10 µs MMIO
//! reconfiguration latency (§5.3.1), never stalling active flows.

use crate::coordinator::status::{MeasuredWindow, SloState};
use crate::flow::{FlowId, FlowKind, Path, Slo};
use crate::shaping::{ShapeMode, TokenBucketParams};
use crate::util::units::Time;

/// What a tenant submits when registering a flow (the PerFlowStatusTable
/// context of §4.3: VM, path, accelerator, SLO, and the message-size hint
/// that keys the Capacity(t, X, N) profile lookup).
#[derive(Debug, Clone)]
pub struct RegisterRequest {
    /// Caller-chosen flow id (unique among registered flows).
    pub flow: FlowId,
    /// Tenant VM the flow belongs to.
    pub vm: usize,
    /// Invocation path (function call / inline NIC / P2P).
    pub path: Path,
    /// Accelerator index in the system's device list.
    pub accel: usize,
    /// Accelerator model name (profile-table key; "storage" for NVMe flows).
    pub accel_name: String,
    /// Accelerator vs storage-read vs storage-write flow.
    pub kind: FlowKind,
    /// The service-level objective the tenant asks to commit.
    pub slo: Slo,
    /// Message size this flow predominantly uses (profiling context key).
    pub size_hint: u64,
}

/// A shaper configuration the dataplane must program at the interface.
#[derive(Debug, Clone, PartialEq)]
pub enum ShaperProgram {
    /// Leave the flow unshaped (latency-critical flows, unmanaged modes).
    Unshaped,
    /// Program a hardware token bucket: install `params`, then retune the
    /// registers to `rate` units/sec (the control plane pre-applies its
    /// shaping headroom so the measured rate lands ON the SLO).
    TokenBucket {
        params: TokenBucketParams,
        rate: f64,
        mode: ShapeMode,
    },
    /// Program a host-software rate limiter (the Host_TS_* baselines).
    Software { rate: f64, mode: ShapeMode },
    /// Hang the flow off the hierarchical shaper tree
    /// ([`crate::shaping::ShaperTree`]) as a *paced leaf* under its
    /// tenant's aggregate node on the flow's engine — the scalable form of
    /// shaping (§5): no per-flow hardware bucket, release driven by the
    /// tree's deficit-round-robin pacing pass. The install also carries
    /// the absolute tenant-aggregate and engine-root envelopes as of this
    /// decision, so one program upserts every level it hangs from.
    Hierarchy {
        /// Tenant aggregate (VM) this leaf hangs off.
        tenant: usize,
        /// Leaf assured rate (units/sec).
        guarantee: f64,
        /// Leaf borrowing cap (units/sec).
        ceiling: f64,
        /// Tenant aggregate assured rate, absolute (units/sec).
        tenant_guarantee: f64,
        /// Tenant aggregate borrowing cap, absolute (units/sec).
        tenant_ceiling: f64,
        /// Engine-root ceiling (units/sec; the admission budget).
        engine_ceiling: f64,
        /// Cost units (bytes vs messages).
        mode: ShapeMode,
    },
}

/// Successful registration / renegotiation outcome.
#[derive(Debug, Clone)]
pub struct Admitted {
    /// Shaping rate (units/sec) now committed in the capacity plan, if the
    /// SLO carries one.
    pub committed_rate: Option<f64>,
    /// Shaper program the dataplane must install for this flow.
    pub program: ShaperProgram,
}

/// Typed control-plane failures.
#[derive(Debug, Clone, PartialEq)]
pub enum ApiError {
    /// Capacity planning refused the SLO (Algorithm 1 lines 7–10).
    AdmissionRejected { reason: String },
    /// The flow id is already registered.
    AlreadyRegistered { flow: FlowId },
    /// The flow id is not registered.
    UnknownFlow { flow: FlowId },
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ApiError::AdmissionRejected { reason } => {
                write!(f, "admission rejected: {reason}")
            }
            ApiError::AlreadyRegistered { flow } => {
                write!(f, "flow {flow} is already registered")
            }
            ApiError::UnknownFlow { flow } => write!(f, "flow {flow} is not registered"),
        }
    }
}

impl std::error::Error for ApiError {}

/// An asynchronous reconfiguration the control plane asks the dataplane to
/// apply (MMIO register writes / path re-routing; the dataplane models the
/// ~10 µs PCIe round-trip latency before the change takes effect).
#[derive(Debug, Clone, PartialEq)]
pub enum Directive {
    /// Reprogram a flow's shaper to a new rate (units/sec). On a tree-
    /// paced leaf this caps the leaf's ceiling at `rate` — the flat
    /// register semantics ("the flow cannot exceed `rate`") preserved.
    SetRate { flow: FlowId, rate: f64 },
    /// Re-route a flow to a less-contended invocation path.
    SwitchPath { flow: FlowId, to: Path },
    /// Tree-install: (re)program a tenant aggregate node on an engine's
    /// shaper tree with an absolute `(guarantee, ceiling)` envelope in
    /// units/sec. Emitted by the hierarchical planner whenever a tenant's
    /// committed sum changes (arrival, departure, renegotiation,
    /// over-commit rebalance).
    SetAggregate {
        /// Engine (accelerator index) whose tree carries the node.
        engine: usize,
        /// Tenant aggregate (VM) to reprogram.
        tenant: usize,
        /// Assured rate of the aggregate (units/sec).
        guarantee: f64,
        /// Borrowing cap of the aggregate (units/sec).
        ceiling: f64,
    },
}

/// Point-in-time view of one registered flow, for `query_status`.
#[derive(Debug, Clone)]
pub struct FlowStatusView {
    /// Flow id.
    pub flow: FlowId,
    /// Tenant VM.
    pub vm: usize,
    /// Current invocation path (may change via `SwitchPath`).
    pub path: Path,
    /// Accelerator index.
    pub accel: usize,
    /// SLO currently in force (tracks renegotiations).
    pub slo: Slo,
    /// Shaping rate currently programmed (units/sec), if shaped.
    pub shaped_rate: Option<f64>,
    /// Meeting / violating / warmup standing of the last window.
    pub state: SloState,
    /// Consecutive violating windows.
    pub violations: u32,
    /// Reconfigurations issued for this flow.
    pub reconfigs: u32,
}

/// The flow-lifecycle protocol between tenants/dataplane and the SLO
/// runtime.
///
/// Implementations: [`crate::api::ArcusControlPlane`] (profile tables +
/// Algorithm 1), [`crate::api::StaticRateControlPlane`] (Host_TS software
/// shaping at the SLO average), and [`crate::api::NoOpControlPlane`]
/// (unmanaged baselines). The dataplane owns the hardware (shapers, DMA
/// routing) and must not reach past this trait into coordinator internals.
pub trait ControlPlane {
    /// Register a flow: admission control plus initial shaper programming.
    fn register_flow(&mut self, req: &RegisterRequest) -> Result<Admitted, ApiError>;

    /// Renegotiate a registered flow's SLO. On rejection the old SLO (and
    /// its shaper program) stays in force.
    fn update_slo(&mut self, flow: FlowId, slo: Slo) -> Result<Admitted, ApiError>;

    /// Deregister a flow, releasing its committed capacity for later
    /// arrivals or renegotiations to claim.
    fn deregister_flow(&mut self, flow: FlowId) -> Result<(), ApiError>;

    /// Current status of one registered flow (None when unknown).
    fn query_status(&self, flow: FlowId) -> Option<FlowStatusView>;

    /// One control-loop tick: ingest the dataplane's measured hardware
    /// counters and emit reconfiguration directives (Algorithm 1 lines
    /// 2–6). `now` is virtual time; `windows` holds one fresh
    /// [`MeasuredWindow`] per registered flow.
    fn tick(&mut self, now: Time, windows: &[(FlowId, MeasuredWindow)]) -> Vec<Directive>;

    /// Does this control plane run a periodic tick at all? (The unmanaged
    /// and statically-shaped baselines do not.)
    fn needs_ticks(&self) -> bool;

    /// Fault-injection / re-profiling hook: scale this plane's *belief*
    /// about `accel`'s capacity by `factor` (the hardware is untouched;
    /// only the table lies). `factor == 1.0` restores the true table.
    /// Default: ignored — the baseline planes hold no profile state.
    fn set_profile_skew(&mut self, _accel: &str, _factor: f64) {}

    /// Implementation name, for reports.
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn api_error_display_is_informative() {
        let e = ApiError::AdmissionRejected { reason: "capacity 1e9, requested 2e9".into() };
        assert!(e.to_string().contains("admission rejected"));
        assert!(e.to_string().contains("capacity"));
        assert_eq!(
            ApiError::UnknownFlow { flow: 7 }.to_string(),
            "flow 7 is not registered"
        );
        assert_eq!(
            ApiError::AlreadyRegistered { flow: 3 }.to_string(),
            "flow 3 is already registered"
        );
    }
}
