//! [`ArcusControlPlane`]: the Algorithm-1 implementation of the
//! control-plane API.
//!
//! Owns the three coordinator data structures — the offline-learned
//! [`ProfileTable`], the [`AccTable`] of reachable paths, and the dynamic
//! [`PerFlowStatusTable`] — and drives [`crate::coordinator::planner`]
//! through the [`ControlPlane`] trait:
//!
//! - `register_flow` → CapacityPlanning(CHECK) + AdmissionControl over the
//!   committed SLO sum in the flow's profiled context;
//! - `update_slo` → the same check with the flow's own commitment excluded
//!   (Scenario 2's mid-run renegotiation);
//! - `deregister_flow` → releases the commitment (tenant churn);
//! - `tick` → SLOViolationChecker + PathSelection + ReshapeDecision, plus
//!   the §6 opportunistic-class refresh, emitted as [`Directive`]s.

use crate::accel::AccelModel;
use crate::coordinator::planner::{self, Admission, PlannerConfig, RejectReason};
use crate::coordinator::status::{FlowStatus, SloState};
use crate::coordinator::{AccTable, PerFlowStatusTable, ProfileTable};
use crate::flow::{FlowId, FlowKind, Path, Slo};
use crate::pcie::fabric::FabricConfig;
use crate::shaping::{ShapeMode, TokenBucketParams};
use crate::util::units::Time;

use super::control::{
    Admitted, ApiError, ControlPlane, Directive, FlowStatusView, RegisterRequest, ShaperProgram,
    TickContext,
};

/// Retry hint attached to transient (capacity) rejections: one control
/// period (§4.3's 100 µs loop) — the soonest the committed picture can
/// have changed.
const RETRY_HINT_PS: Time = 100_000_000;

/// Map a planner rejection into the structured API error: capacity
/// pressure is transient (carry a retry hint), everything else is
/// structural (no hint — retrying the identical request changes nothing).
fn reject_to_error(reason: RejectReason) -> ApiError {
    let retry_after = match &reason {
        RejectReason::CapacityExceeded { .. } => Some(RETRY_HINT_PS),
        _ => None,
    };
    ApiError::Rejection { reason, retry_after }
}

/// The Arcus SLO runtime behind the [`ControlPlane`] trait.
pub struct ArcusControlPlane {
    cfg: PlannerConfig,
    profile: ProfileTable,
    acc_table: AccTable,
    status: PerFlowStatusTable,
    /// The true (unskewed) profile table, saved while any `ProfileSkew`
    /// fault mis-states `profile`; restored when the last skew heals.
    pristine_profile: Option<ProfileTable>,
    /// Active skews by accelerator name — independent faults on different
    /// accelerators may overlap, and healing one must not heal the others.
    profile_skews: Vec<(String, f64)>,
    /// Hierarchical shaping (§5 at scale): commit tenant aggregates on the
    /// per-engine shaper tree and pace committed flows as tree leaves
    /// instead of per-flow hardware buckets.
    hierarchical: bool,
    /// Tenant-aggregate envelopes `(guarantee, ceiling)` last announced to
    /// the dataplane, keyed by `(engine, tenant)`; `tick` diffs against it
    /// and emits `SetAggregate` tree-install directives for changes.
    announced: std::collections::BTreeMap<(usize, usize), (f64, f64)>,
    /// Engine-root budgets last used for tree installs, by accelerator.
    engine_budgets: std::collections::BTreeMap<usize, f64>,
}

impl ArcusControlPlane {
    /// A control plane over explicit profile/path tables.
    pub fn new(profile: ProfileTable, acc_table: AccTable, cfg: PlannerConfig) -> Self {
        ArcusControlPlane {
            cfg,
            profile,
            acc_table,
            status: PerFlowStatusTable::default(),
            pristine_profile: None,
            profile_skews: Vec::new(),
            hierarchical: false,
            announced: std::collections::BTreeMap::new(),
            engine_budgets: std::collections::BTreeMap::new(),
        }
    }

    /// Enable (or disable) hierarchical shaping: committed and
    /// best-effort accelerator flows are programmed as shaper-tree leaves
    /// under per-tenant aggregates, and `tick` maintains the aggregates
    /// with `SetAggregate` directives. Storage flows keep flat programs
    /// (the SSD is its own capacity authority), and so do IOPS-SLO
    /// accelerator flows — their message-denominated budgets are not
    /// commensurable with the bytes-denominated tree pool.
    pub fn with_hierarchy(mut self, on: bool) -> Self {
        self.hierarchical = on;
        self
    }

    /// Is hierarchical shaping enabled?
    pub fn hierarchical(&self) -> bool {
        self.hierarchical
    }

    /// Learn the profile table for a device list on a PCIe fabric and
    /// register every accelerator's reachable paths — the construction the
    /// simulator and serving runtime share.
    pub fn from_models(models: &[AccelModel], fabric: &FabricConfig, cfg: PlannerConfig) -> Self {
        let profile = ProfileTable::learn(models, fabric);
        let mut acc_table = AccTable::default();
        for m in models {
            acc_table.register(
                m.name,
                vec![
                    Path::FunctionCall,
                    Path::InlineNicRx,
                    Path::InlineNicTx,
                    Path::InlineP2p,
                ],
            );
        }
        Self::new(profile, acc_table, cfg)
    }

    /// Read-only view of the flow registry (observability / tests).
    pub fn status_table(&self) -> &PerFlowStatusTable {
        &self.status
    }

    /// Read-only view of the profile table.
    pub fn profile(&self) -> &ProfileTable {
        &self.profile
    }

    /// The planner tuning in force.
    pub fn planner_cfg(&self) -> &PlannerConfig {
        &self.cfg
    }

    /// Record a shaping rate some *outer* control tier (the adaptive
    /// wrapper) has directed the dataplane to program for `flow`,
    /// overriding whatever this plane last asked for. Keeping the status
    /// row honest matters: the planner's decay and over-commit
    /// convergence logic compare against `shaped_rate`, so a wrapper
    /// that issues its own `SetRate` directives without recording them
    /// here would leave the inner plane fighting a stale picture.
    pub fn note_shaped_rate(&mut self, flow: FlowId, rate: f64) {
        if let Some(row) = self.status.get_mut(flow) {
            let mode = row
                .slo
                .required_rate()
                .map(|(_, m)| m)
                .unwrap_or(ShapeMode::Gbps);
            row.shaped_rate = Some(rate);
            row.params = Some(TokenBucketParams::for_rate(rate, mode));
            row.reconfigs += 1;
        }
    }

    /// The engine-root budget (bytes/sec) last used for tree installs on
    /// `engine`, if hierarchical registrations have established one.
    pub fn engine_budget_for(&self, engine: usize) -> Option<f64> {
        self.engine_budgets.get(&engine).copied()
    }

    /// Record a tenant-aggregate envelope some outer tier has announced
    /// to the dataplane, so this plane's `SetAggregate` diffing does not
    /// immediately re-announce (and thereby revert) it.
    pub fn note_announced_aggregate(
        &mut self,
        engine: usize,
        tenant: usize,
        guarantee: f64,
        ceiling: f64,
    ) {
        self.announced.insert((engine, tenant), (guarantee, ceiling));
    }

    /// Storage-contract program: the SSD is its own capacity authority, so
    /// the bucket derives directly from the SLO rate with the shaping
    /// headroom pre-applied — no accelerator-profile lookup, at
    /// registration and renegotiation alike.
    fn storage_program(&self, rate: f64, mode: ShapeMode) -> ShaperProgram {
        let shaped = rate * self.cfg.shaping_headroom;
        ShaperProgram::TokenBucket {
            params: TokenBucketParams::for_rate(shaped, mode),
            rate: shaped,
            mode,
        }
    }

    /// Headroom available to an opportunistic flow on its accelerator:
    /// profiled capacity net of the admission reserve and every committed
    /// rate, floored at 2% of capacity so the class never fully starves.
    fn opportunistic_rate(&self, flow: FlowId) -> f64 {
        let Some(row) = self.status.get(flow) else { return 0.0 };
        let n = self.status.count_on_accel(row.accel).max(1);
        let cap = self
            .profile
            .capacity(&row.accel_name, row.path, row.size_hint, n)
            .map(|e| e.capacity.as_bits_per_sec() / 8.0)
            .unwrap_or(0.0);
        let committed = self.status.committed_rate(row.accel);
        (cap * (1.0 - self.cfg.admission_headroom) - committed).max(cap * 0.02)
    }

    /// Engine-root budget in bytes/sec for a flow's profiled context: the
    /// same capacity (net of the admission reserve) the CHECK plans
    /// against, used as the tree's root and tenant ceilings.
    fn engine_budget(&self, accel: usize, accel_name: &str, path: Path, size_hint: u64) -> f64 {
        let n = self.status.count_on_accel(accel).max(1);
        self.profile
            .capacity(accel_name, path, size_hint, n)
            .map(|e| {
                e.capacity.as_bits_per_sec() / 8.0 * (1.0 - self.cfg.admission_headroom)
            })
            .unwrap_or(f64::INFINITY)
    }

    /// Build the tree-leaf program for an (already registered) flow:
    /// `guarantee` is the leaf's assured rate, `ceiling` its borrowing
    /// cap; the install carries the tenant's absolute committed aggregate
    /// and the engine budget so one program upserts every tree level.
    /// Records the announced envelope so `tick` does not re-emit it.
    fn hierarchy_program(
        &mut self,
        flow: FlowId,
        guarantee: f64,
        ceiling: f64,
        mode: ShapeMode,
    ) -> ShaperProgram {
        let row = self.status.get(flow).expect("hierarchy program for unregistered flow");
        let (accel, vm) = (row.accel, row.vm);
        let (name, path, hint) = (row.accel_name.clone(), row.path, row.size_hint);
        let budget = self.engine_budget(accel, &name, path, hint);
        // The registering flow's own tenant sum only — scanning the full
        // aggregate table here would make a 10k-flow registration storm
        // O(n²) with allocations (tick-time maintenance still diffs the
        // complete table via `planner::tenant_aggregates`).
        let tenant_guarantee: f64 = self
            .status
            .iter()
            .filter(|r| r.accel == accel && r.vm == vm && r.accel_name != "storage")
            .filter_map(|r| match r.slo.required_rate() {
                Some((rate, ShapeMode::Gbps)) => Some(rate),
                _ => None,
            })
            .sum::<f64>()
            * self.cfg.shaping_headroom;
        self.announced.insert((accel, vm), (tenant_guarantee, budget));
        self.engine_budgets.insert(accel, budget);
        ShaperProgram::Hierarchy {
            tenant: vm,
            guarantee,
            ceiling: ceiling.min(budget),
            tenant_guarantee,
            tenant_ceiling: budget,
            engine_ceiling: budget,
            mode,
        }
    }

    /// Hierarchical `tick` maintenance: diff the current per-(engine,
    /// tenant) committed aggregates against what the dataplane last heard
    /// and emit `SetAggregate` tree-install directives for the deltas
    /// (arrivals are announced synchronously by their install program;
    /// departures and renegotiations surface here).
    fn refresh_aggregates(&mut self, now: Time) -> Vec<Directive> {
        let mut out = Vec::new();
        let mut current = std::collections::BTreeMap::new();
        for (accel, vm, sum) in planner::tenant_aggregates(&self.status) {
            current.insert((accel, vm), sum * self.cfg.shaping_headroom);
        }
        // Changed or new aggregates.
        for (&(accel, vm), &guarantee) in &current {
            let ceiling = self
                .engine_budgets
                .get(&accel)
                .copied()
                .unwrap_or(f64::INFINITY);
            let stale = match self.announced.get(&(accel, vm)) {
                Some(&(g, c)) => {
                    (g - guarantee).abs() > g.abs().max(1.0) * 1e-9 || c != ceiling
                }
                None => true,
            };
            if stale {
                self.announced.insert((accel, vm), (guarantee, ceiling));
                out.push(Directive::set_aggregate(now, accel, vm, guarantee, ceiling));
            }
        }
        // Vanished aggregates (every committed flow departed): release the
        // guarantee so siblings can borrow the freed budget.
        let gone: Vec<(usize, usize)> = self
            .announced
            .keys()
            .filter(|k| !current.contains_key(k))
            .copied()
            .collect();
        for (accel, vm) in gone {
            let ceiling = self
                .engine_budgets
                .get(&accel)
                .copied()
                .unwrap_or(f64::INFINITY);
            self.announced.remove(&(accel, vm));
            out.push(Directive::set_aggregate(now, accel, vm, 0.0, ceiling));
        }
        out
    }

    /// §6's no-guarantee class: back a best-effort flow off multiplicatively
    /// whenever a committed flow on the same engine is violating (the
    /// harvest must never cost an SLO), otherwise creep back up toward the
    /// profiled headroom.
    fn refresh_opportunistic(&mut self, now: Time) -> Vec<Directive> {
        let mut violated_accels: Vec<usize> = Vec::new();
        for row in self.status.iter() {
            if row.state == SloState::Violating
                && row.violations >= self.cfg.reshape_after
                && !matches!(row.slo, Slo::BestEffort)
                && !violated_accels.contains(&row.accel)
            {
                violated_accels.push(row.accel);
            }
        }
        let candidates: Vec<FlowId> = self
            .status
            .iter()
            .filter(|r| matches!(r.slo, Slo::BestEffort) && r.shaped_rate.is_some())
            .map(|r| r.flow)
            .collect();
        let mut out = Vec::new();
        for flow in candidates {
            let headroom = self.opportunistic_rate(flow);
            let (current, accel) = match self.status.get(flow) {
                Some(r) => (r.shaped_rate.unwrap_or(0.0), r.accel),
                None => continue,
            };
            let target = if violated_accels.contains(&accel) {
                (current * 0.6).max(headroom * 0.02)
            } else {
                (current * 1.10).min(headroom)
            };
            if (current - target).abs() / current.max(1.0) > 0.02 {
                let rate = target.max(1.0);
                // Track the *nominal* register rate the bucket will realize,
                // so the next refresh compares against what the hardware
                // actually shapes to (exactly as reading it back would).
                let nominal =
                    TokenBucketParams::for_rate(rate, ShapeMode::Gbps).nominal_rate();
                if let Some(r) = self.status.get_mut(flow) {
                    r.shaped_rate = Some(nominal);
                }
                out.push(Directive::set_rate(now, flow, rate));
            }
        }
        out
    }
}

impl ControlPlane for ArcusControlPlane {
    fn register_flow(&mut self, req: &RegisterRequest) -> Result<Admitted, ApiError> {
        if self.status.get(req.flow).is_some() {
            return Err(ApiError::AlreadyRegistered { flow: req.flow });
        }
        let mut row = FlowStatus::new(
            req.flow,
            req.vm,
            req.path,
            req.accel,
            &req.accel_name,
            req.slo,
            req.size_hint,
        );
        // Storage flows bypass the accelerator profile: the SSD is its own
        // capacity authority; shape at the SLO rate.
        if req.kind != FlowKind::Accel {
            let (committed_rate, program) = match req.slo.required_rate() {
                Some((rate, mode)) => {
                    row.shaped_rate = Some(rate);
                    (Some(rate), self.storage_program(rate, mode))
                }
                None => (None, ShaperProgram::Unshaped),
            };
            self.status.register(row);
            return Ok(Admitted { committed_rate, program });
        }
        match req.slo {
            Slo::BestEffort => {
                // Opportunistic class (§6): shaped to the current headroom,
                // refreshed every control tick. Registered first so the
                // headroom computation counts this flow in N.
                self.status.register(row);
                let rate = self.opportunistic_rate(req.flow).max(1.0);
                if self.hierarchical {
                    // Zero-guarantee tree leaf capped at the opportunistic
                    // headroom: the DRR borrow pass hands it exactly the
                    // unused sibling budget the §6 class harvests.
                    if let Some(r) = self.status.get_mut(req.flow) {
                        r.shaped_rate = Some(rate);
                    }
                    let program =
                        self.hierarchy_program(req.flow, 0.0, rate, ShapeMode::Gbps);
                    return Ok(Admitted { committed_rate: None, program });
                }
                let params = TokenBucketParams::for_rate(rate, ShapeMode::Gbps);
                if let Some(r) = self.status.get_mut(req.flow) {
                    r.shaped_rate = Some(params.nominal_rate());
                }
                Ok(Admitted {
                    committed_rate: None,
                    program: ShaperProgram::TokenBucket {
                        params,
                        rate,
                        mode: ShapeMode::Gbps,
                    },
                })
            }
            Slo::Latency { .. } => {
                // Latency-critical flows run unshaped; Arcus protects them
                // by shaping everyone else.
                self.status.register(row);
                Ok(Admitted { committed_rate: None, program: ShaperProgram::Unshaped })
            }
            _ => {
                let verdict = planner::admission_control(
                    &self.cfg,
                    &self.profile,
                    &self.status,
                    req.accel,
                    &req.accel_name,
                    req.path,
                    req.size_hint,
                    &req.slo,
                );
                match verdict {
                    Admission::Accept { rate, params } => {
                        let mode = req
                            .slo
                            .required_rate()
                            .map(|(_, m)| m)
                            .unwrap_or(ShapeMode::Gbps);
                        row.shaped_rate = Some(rate);
                        self.status.register(row);
                        if self.hierarchical && mode == ShapeMode::Gbps {
                            // Tree leaf: guaranteed its shaped rate, free
                            // to borrow idle sibling budget up to the
                            // engine ceiling (work-conserving §5 shaping).
                            // IOPS-SLO flows fall through to a flat bucket
                            // — message-denominated budgets are not
                            // commensurable with the bytes-denominated
                            // tree pool.
                            let shaped = rate * self.cfg.shaping_headroom;
                            let program = self.hierarchy_program(
                                req.flow,
                                shaped,
                                f64::INFINITY,
                                mode,
                            );
                            return Ok(Admitted { committed_rate: Some(rate), program });
                        }
                        Ok(Admitted {
                            committed_rate: Some(rate),
                            // Program slightly above the SLO so the measured
                            // rate lands ON it.
                            program: ShaperProgram::TokenBucket {
                                params,
                                rate: rate * self.cfg.shaping_headroom,
                                mode,
                            },
                        })
                    }
                    Admission::Reject { reason } => Err(reject_to_error(reason)),
                }
            }
        }
    }

    fn update_slo(&mut self, flow: FlowId, slo: Slo) -> Result<Admitted, ApiError> {
        let Some(is_storage) = self.status.get(flow).map(|r| r.accel_name == "storage") else {
            return Err(ApiError::UnknownFlow { flow });
        };
        // Storage flows bypass the accelerator profile on renegotiation
        // exactly as they do at registration: the SSD is its own capacity
        // authority, so the new rate is accepted and shaped directly.
        if is_storage {
            let contract = slo
                .required_rate()
                .map(|(rate, mode)| (rate, self.storage_program(rate, mode)));
            let row = self.status.get_mut(flow).expect("checked above");
            row.slo = slo;
            row.violations = 0;
            row.state = SloState::Warmup;
            return Ok(match contract {
                Some((rate, program)) => {
                    row.shaped_rate = Some(rate);
                    row.reconfigs += 1;
                    Admitted { committed_rate: Some(rate), program }
                }
                None => {
                    row.shaped_rate = None;
                    row.params = None;
                    Admitted { committed_rate: None, program: ShaperProgram::Unshaped }
                }
            });
        }
        let verdict =
            planner::renegotiation_control(&self.cfg, &self.profile, &self.status, flow, &slo);
        match verdict {
            Admission::Accept { rate, params } => {
                let headroom = self.cfg.shaping_headroom;
                {
                    let row = self.status.get_mut(flow).expect("checked above");
                    row.slo = slo;
                    // A fresh contract restarts measurement: hysteresis
                    // resets and the next windows are judged against the
                    // new target.
                    row.violations = 0;
                    row.state = SloState::Warmup;
                }
                match slo.required_rate() {
                    Some((_, mode)) => {
                        {
                            let row = self.status.get_mut(flow).expect("checked above");
                            row.shaped_rate = Some(rate);
                            row.params = Some(params);
                            row.reconfigs += 1;
                        }
                        if self.hierarchical && mode == ShapeMode::Gbps {
                            // See register_flow: IOPS contracts keep flat
                            // buckets even under hierarchy.
                            let shaped = rate * headroom;
                            let program =
                                self.hierarchy_program(flow, shaped, f64::INFINITY, mode);
                            return Ok(Admitted { committed_rate: Some(rate), program });
                        }
                        Ok(Admitted {
                            committed_rate: Some(rate),
                            program: ShaperProgram::TokenBucket {
                                params,
                                rate: rate * headroom,
                                mode,
                            },
                        })
                    }
                    None if matches!(slo, Slo::BestEffort) => {
                        // Dropping to the opportunistic class gets the same
                        // §6 program as a fresh best-effort registration —
                        // the harvest must never run unshaped. (The row's
                        // slo is already BestEffort, so the headroom
                        // computation no longer counts the old commitment.)
                        let be_rate = self.opportunistic_rate(flow).max(1.0);
                        if self.hierarchical {
                            {
                                let row =
                                    self.status.get_mut(flow).expect("checked above");
                                row.shaped_rate = Some(be_rate);
                                row.params = None;
                                row.reconfigs += 1;
                            }
                            let program = self.hierarchy_program(
                                flow,
                                0.0,
                                be_rate,
                                ShapeMode::Gbps,
                            );
                            return Ok(Admitted { committed_rate: None, program });
                        }
                        let be_params =
                            TokenBucketParams::for_rate(be_rate, ShapeMode::Gbps);
                        let row = self.status.get_mut(flow).expect("checked above");
                        row.shaped_rate = Some(be_params.nominal_rate());
                        row.params = Some(be_params);
                        row.reconfigs += 1;
                        Ok(Admitted {
                            committed_rate: None,
                            program: ShaperProgram::TokenBucket {
                                params: be_params,
                                rate: be_rate,
                                mode: ShapeMode::Gbps,
                            },
                        })
                    }
                    None => {
                        // Latency-critical flows run unshaped by design
                        // (Arcus protects them by shaping everyone else).
                        let row = self.status.get_mut(flow).expect("checked above");
                        row.shaped_rate = None;
                        row.params = None;
                        Ok(Admitted {
                            committed_rate: None,
                            program: ShaperProgram::Unshaped,
                        })
                    }
                }
            }
            Admission::Reject { reason } => Err(reject_to_error(reason)),
        }
    }

    fn deregister_flow(&mut self, flow: FlowId) -> Result<(), ApiError> {
        match self.status.deregister(flow) {
            Some(_) => Ok(()),
            None => Err(ApiError::UnknownFlow { flow }),
        }
    }

    fn query_status(&self, flow: FlowId) -> Option<FlowStatusView> {
        self.status.get(flow).map(|r| FlowStatusView {
            flow: r.flow,
            vm: r.vm,
            path: r.path,
            accel: r.accel,
            slo: r.slo,
            shaped_rate: r.shaped_rate,
            state: r.state,
            violations: r.violations,
            reconfigs: r.reconfigs,
        })
    }

    fn set_profile_skew(&mut self, accel: &str, factor: f64) {
        // Skews never compound: the active set is re-applied to the true
        // table on every change, so factor 1.0 restores an accelerator
        // exactly (byte-identical, not a round-tripped reciprocal) without
        // disturbing skews still active on other accelerators.
        self.profile_skews.retain(|(name, _)| name != accel);
        if (factor - 1.0).abs() >= 1e-12 {
            self.profile_skews.push((accel.to_string(), factor));
        }
        if self.profile_skews.is_empty() {
            // Last skew healed (or a no-op heal): the true table is back.
            if let Some(p) = self.pristine_profile.take() {
                self.profile = p;
            }
            return;
        }
        let pristine = self
            .pristine_profile
            .take()
            .unwrap_or_else(|| self.profile.clone());
        self.profile = pristine.clone();
        for (name, f) in &self.profile_skews {
            self.profile.scale_accel(name, *f);
        }
        self.pristine_profile = Some(pristine);
    }

    fn tick(&mut self, ctx: &TickContext<'_>) -> Vec<Directive> {
        let now = ctx.now;
        // 1. Ingest the hardware counters (SLOViolationChecker).
        for &(flow, w) in ctx.windows {
            self.status.record_window(flow, w);
        }
        // 2. Plan: path selection + reshape decisions for violating flows.
        let mut actions =
            planner::run_tick(&self.cfg, &self.profile, &self.acc_table, &self.status);
        // 2b. Over-commit reconciliation (profile mis-estimation): clamp
        // committed flows on over-committed engines to their true shares,
        // and suppress compensation boosts there — boosting cannot conjure
        // capacity that does not exist.
        let frozen = planner::overcommitted_accels(&self.cfg, &self.profile, &self.status);
        if !frozen.is_empty() {
            actions.retain(|a| {
                let flow = match a {
                    planner::Action::Reshape { flow, .. }
                    | planner::Action::SwitchPath { flow, .. } => *flow,
                };
                self.status.get(flow).map_or(true, |r| !frozen.contains(&r.accel))
            });
            actions.extend(planner::rebalance_overcommit(
                &self.cfg,
                &self.profile,
                &self.status,
                &frozen,
            ));
        }
        let mut out = Vec::with_capacity(actions.len());
        for a in actions {
            match a {
                planner::Action::Reshape { flow, rate, params } => {
                    if let Some(row) = self.status.get_mut(flow) {
                        row.shaped_rate = Some(rate);
                        row.params = Some(params);
                        row.reconfigs += 1;
                    }
                    out.push(Directive::set_rate(now, flow, rate));
                }
                planner::Action::SwitchPath { flow, to } => {
                    if let Some(row) = self.status.get_mut(flow) {
                        row.path = to;
                        row.reconfigs += 1;
                    }
                    out.push(Directive::switch_path(now, flow, to));
                }
            }
        }
        // 3. Opportunistic-class refresh (§6).
        out.extend(self.refresh_opportunistic(now));
        // 4. Tree maintenance (hierarchical mode): announce tenant-
        //    aggregate changes (departures, renegotiations, rebalances)
        //    as SetAggregate tree-install directives.
        if self.hierarchical {
            out.extend(self.refresh_aggregates(now));
        }
        out
    }

    fn needs_ticks(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "arcus"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::status::MeasuredWindow;
    use crate::util::units::Rate;

    use super::super::control::DirectiveKind;

    fn cp() -> ArcusControlPlane {
        ArcusControlPlane::from_models(
            &[AccelModel::ipsec_32g()],
            &FabricConfig::gen3_x8(),
            PlannerConfig::default(),
        )
    }

    fn req(flow: FlowId, slo: Slo) -> RegisterRequest {
        RegisterRequest {
            flow,
            vm: flow,
            path: Path::FunctionCall,
            accel: 0,
            accel_name: "ipsec".into(),
            kind: FlowKind::Accel,
            slo,
            size_hint: 1500,
        }
    }

    #[test]
    fn register_admits_within_capacity_and_rejects_beyond() {
        let mut cp = cp();
        // Engine sustains ~26 Gbps at 1500 B; 12 + 12 fit, +15 must not.
        let a = cp.register_flow(&req(0, Slo::gbps(12.0))).unwrap();
        assert!(a.committed_rate.unwrap() > 0.0);
        assert!(matches!(a.program, ShaperProgram::TokenBucket { .. }));
        cp.register_flow(&req(1, Slo::gbps(12.0))).unwrap();
        let e = cp.register_flow(&req(2, Slo::gbps(15.0))).unwrap_err();
        assert!(
            matches!(
                e,
                ApiError::Rejection {
                    reason: RejectReason::CapacityExceeded { .. },
                    retry_after: Some(_),
                }
            ),
            "{e}"
        );
        assert!(cp.query_status(2).is_none());
    }

    #[test]
    fn duplicate_registration_is_an_error() {
        let mut cp = cp();
        cp.register_flow(&req(0, Slo::gbps(5.0))).unwrap();
        let e = cp.register_flow(&req(0, Slo::gbps(5.0))).unwrap_err();
        assert_eq!(e, ApiError::AlreadyRegistered { flow: 0 });
    }

    #[test]
    fn departure_releases_capacity_for_later_arrivals() {
        let mut cp = cp();
        cp.register_flow(&req(0, Slo::gbps(12.0))).unwrap();
        cp.register_flow(&req(1, Slo::gbps(12.0))).unwrap();
        assert!(cp.register_flow(&req(2, Slo::gbps(12.0))).is_err());
        cp.deregister_flow(0).unwrap();
        assert!(cp.query_status(0).is_none());
        // The freed 12 Gbps admits the previously-rejected request.
        cp.register_flow(&req(2, Slo::gbps(12.0))).unwrap();
        assert!(cp.deregister_flow(0).is_err(), "double deregister");
    }

    #[test]
    fn renegotiation_checks_capacity_excluding_own_commitment() {
        let mut cp = cp();
        cp.register_flow(&req(0, Slo::gbps(10.0))).unwrap();
        cp.register_flow(&req(1, Slo::gbps(10.0))).unwrap();
        // 10 → 14 fits (14 + 10 < ~24.6 budget); the flow's own 10 must not
        // be double-counted.
        let a = cp.update_slo(0, Slo::gbps(14.0)).unwrap();
        assert!((a.committed_rate.unwrap() - 14e9 / 8.0).abs() < 1.0);
        assert_eq!(cp.query_status(0).unwrap().slo, Slo::gbps(14.0));
        // 14 → 20 exceeds what flow 1 leaves free: rejected, SLO kept.
        assert!(cp.update_slo(0, Slo::gbps(20.0)).is_err());
        assert_eq!(cp.query_status(0).unwrap().slo, Slo::gbps(14.0));
        // Unknown flows are a typed error.
        assert_eq!(
            cp.update_slo(9, Slo::gbps(1.0)).unwrap_err(),
            ApiError::UnknownFlow { flow: 9 }
        );
        // Dropping to best-effort keeps the flow shaped (the §6
        // opportunistic program), never unshaped.
        let a = cp.update_slo(0, Slo::BestEffort).unwrap();
        assert!(a.committed_rate.is_none());
        match a.program {
            ShaperProgram::TokenBucket { rate, .. } => assert!(rate >= 1.0),
            other => panic!("expected opportunistic bucket, got {other:?}"),
        }
        assert!(cp.query_status(0).unwrap().shaped_rate.is_some());
    }

    #[test]
    fn storage_flows_renegotiate_without_accelerator_profile() {
        // The SSD is its own capacity authority: the accelerator profile
        // has no "storage" entries, yet renegotiation must succeed exactly
        // as registration does.
        let mut cp = cp();
        let mut r = req(0, Slo::iops(200_000.0));
        r.kind = FlowKind::StorageRead;
        r.accel_name = "storage".into();
        cp.register_flow(&r).unwrap();
        let a = cp.update_slo(0, Slo::iops(300_000.0)).unwrap();
        assert!((a.committed_rate.unwrap() - 300_000.0).abs() < 1.0);
        assert!(matches!(a.program, ShaperProgram::TokenBucket { .. }));
        assert_eq!(cp.query_status(0).unwrap().slo, Slo::iops(300_000.0));
    }

    #[test]
    fn best_effort_gets_positive_opportunistic_program() {
        let mut cp = cp();
        cp.register_flow(&req(0, Slo::gbps(10.0))).unwrap();
        let a = cp.register_flow(&req(1, Slo::BestEffort)).unwrap();
        assert!(a.committed_rate.is_none());
        match a.program {
            ShaperProgram::TokenBucket { rate, .. } => assert!(rate >= 1.0),
            other => panic!("expected token bucket, got {other:?}"),
        }
        // The registry tracks the nominal programmed rate.
        assert!(cp.query_status(1).unwrap().shaped_rate.unwrap() > 0.0);
    }

    #[test]
    fn profile_skew_overadmits_then_heals_to_clamped_rates() {
        let mut cp = cp();
        // True budget at 1500 B is ~24.6 Gbps; a 1.5× skew admits 3 × 12.
        cp.set_profile_skew("ipsec", 1.5);
        for i in 0..3 {
            cp.register_flow(&req(i, Slo::gbps(12.0)))
                .unwrap_or_else(|e| panic!("flow {i} rejected under skew: {e}"));
        }
        // Healing the table exposes the over-commitment; the first tick
        // emits clamping directives bringing the programmed sum under the
        // true budget.
        cp.set_profile_skew("ipsec", 1.0);
        let ds = cp.tick(&TickContext::new(0, &[]));
        assert!(!ds.is_empty(), "expected clamping directives");
        let sum: f64 = (0..3)
            .filter_map(|f| cp.query_status(f).and_then(|v| v.shaped_rate))
            .sum();
        let entry = cp.profile().capacity("ipsec", Path::FunctionCall, 1500, 3).unwrap();
        let budget = entry.capacity.as_bits_per_sec() / 8.0
            * (1.0 - cp.planner_cfg().admission_headroom);
        assert!(sum <= budget * 1.001, "programmed {sum:.3e} > true budget {budget:.3e}");
        // The pass converges: a second tick emits no further clamps.
        assert!(cp.tick(&TickContext::new(0, &[])).is_empty());
    }

    #[test]
    fn skews_on_different_accels_are_independent() {
        let mut cp = ArcusControlPlane::from_models(
            &[AccelModel::ipsec_32g(), AccelModel::aes_128()],
            &FabricConfig::gen3_x8(),
            PlannerConfig::default(),
        );
        let cap = |cp: &ArcusControlPlane, name: &str| {
            cp.profile()
                .capacity(name, Path::FunctionCall, 1500, 2)
                .unwrap()
                .capacity
                .0
        };
        let (ipsec0, aes0) = (cap(&cp, "ipsec"), cap(&cp, "aes128"));
        cp.set_profile_skew("ipsec", 2.0);
        cp.set_profile_skew("aes128", 0.5);
        // Skewing aes128 must not disturb ipsec's active skew.
        assert!((cap(&cp, "ipsec") - ipsec0 * 2.0).abs() < 1.0);
        assert!((cap(&cp, "aes128") - aes0 * 0.5).abs() < 1.0);
        // Healing ipsec keeps aes128's skew in force...
        cp.set_profile_skew("ipsec", 1.0);
        assert_eq!(cap(&cp, "ipsec").to_bits(), ipsec0.to_bits());
        assert!((cap(&cp, "aes128") - aes0 * 0.5).abs() < 1.0);
        // ...and healing the last skew restores the exact true table.
        cp.set_profile_skew("aes128", 1.0);
        assert_eq!(cap(&cp, "aes128").to_bits(), aes0.to_bits());
    }

    #[test]
    fn skew_restores_byte_identical_table() {
        let mut cp = cp();
        let before = cp
            .profile()
            .capacity("ipsec", Path::FunctionCall, 1500, 2)
            .unwrap()
            .capacity
            .0;
        cp.set_profile_skew("ipsec", 0.4);
        let skewed = cp
            .profile()
            .capacity("ipsec", Path::FunctionCall, 1500, 2)
            .unwrap()
            .capacity
            .0;
        assert!((skewed - before * 0.4).abs() < 1.0);
        cp.set_profile_skew("ipsec", 1.0);
        let after = cp
            .profile()
            .capacity("ipsec", Path::FunctionCall, 1500, 2)
            .unwrap()
            .capacity
            .0;
        assert_eq!(before.to_bits(), after.to_bits(), "heal must be exact");
    }

    #[test]
    fn hierarchical_mode_emits_tree_programs_and_aggregate_releases() {
        let mut cp = ArcusControlPlane::from_models(
            &[AccelModel::ipsec_32g()],
            &FabricConfig::gen3_x8(),
            PlannerConfig::default(),
        )
        .with_hierarchy(true);
        assert!(cp.hierarchical());
        // Committed registration comes back as a tree-leaf install carrying
        // the tenant and engine envelopes.
        let a = cp.register_flow(&req(0, Slo::gbps(10.0))).unwrap();
        match a.program {
            ShaperProgram::Hierarchy {
                tenant,
                guarantee,
                ceiling,
                tenant_guarantee,
                tenant_ceiling,
                engine_ceiling,
                ..
            } => {
                assert_eq!(tenant, 0, "tenant aggregate keys on the VM");
                assert!(guarantee > 0.0 && ceiling >= guarantee);
                // The sole flow's guarantee IS its tenant's aggregate.
                assert!((tenant_guarantee - guarantee).abs() / guarantee < 1e-9);
                assert!(engine_ceiling >= tenant_guarantee);
                assert!((tenant_ceiling - engine_ceiling).abs() < 1.0);
            }
            other => panic!("expected hierarchy program, got {other:?}"),
        }
        // Best-effort joins as a zero-guarantee leaf (borrow-only).
        let b = cp.register_flow(&req(1, Slo::BestEffort)).unwrap();
        match b.program {
            ShaperProgram::Hierarchy { guarantee, ceiling, .. } => {
                assert_eq!(guarantee, 0.0);
                assert!(ceiling >= 1.0);
            }
            other => panic!("expected hierarchy program, got {other:?}"),
        }
        // A departure releases the tenant's aggregate: the next tick
        // announces it as a SetAggregate tree-install directive.
        cp.deregister_flow(0).unwrap();
        let ds = cp.tick(&TickContext::new(0, &[]));
        assert!(
            ds.iter().any(|d| matches!(
                &d.kind,
                DirectiveKind::SetAggregate { engine: 0, tenant: 0, guarantee, .. }
                    if *guarantee == 0.0
            )),
            "expected a zero-guarantee SetAggregate for the departed tenant: {ds:?}"
        );
        // The diff converges: a second tick announces nothing further.
        assert!(cp
            .tick(&TickContext::new(0, &[]))
            .iter()
            .all(|d| !matches!(d.kind, DirectiveKind::SetAggregate { .. })));
    }

    #[test]
    fn tick_reshapes_violating_flow_through_directives() {
        let mut cp = cp();
        cp.register_flow(&req(0, Slo::gbps(10.0))).unwrap();
        // Three consecutive windows at 8 of 10 Gbps: hysteresis (2) passes
        // and a SetRate boost comes out.
        let w = MeasuredWindow {
            span: crate::util::units::MILLIS,
            bytes: 1_000_000,
            ops: 667,
            p99_latency: None,
        };
        let mut boosts = Vec::new();
        for _ in 0..3 {
            let windows = [(0, w)];
            boosts = cp.tick(&TickContext::new(0, &windows));
        }
        let prev = 10e9 / 8.0;
        match &boosts[..] {
            [Directive { kind: DirectiveKind::SetRate { flow: 0, rate }, .. }] => {
                assert!(*rate > prev, "boosted rate {rate:.3e}");
            }
            other => panic!("expected one boost, got {other:?}"),
        }
        let _ = Rate::gbps(1.0);
    }
}
