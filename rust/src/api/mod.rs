//! First-class control-plane API: the flow-lifecycle protocol between
//! tenants / the dataplane and the SLO runtime.
//!
//! The [`ControlPlane`] trait is the seam of the system: *everything* that
//! admits, reshapes, renegotiates, or retires a flow goes through it. The
//! DES engine ([`crate::system::engine`]) is one consumer; the wall-clock
//! serving runtime and future multi-node frontends are the others — none of
//! them may touch the coordinator's tables directly.
//!
//! - [`control`] — the trait plus its typed request/response/error/directive
//!   vocabulary ([`RegisterRequest`], [`Admitted`], [`ShaperProgram`],
//!   [`Directive`], [`ApiError`], [`FlowStatusView`]).
//! - [`arcus`] — [`ArcusControlPlane`]: profile tables + Algorithm 1.
//! - [`adaptive`] — [`AdaptiveControlPlane`]: closed-loop AIMD wrapper over
//!   the Arcus plane, driven by the [`ObsView`] telemetry in
//!   [`TickContext`].
//! - [`baseline`] — [`NoOpControlPlane`] (Host_no_TS / Bypassed_PANIC) and
//!   [`StaticRateControlPlane`] (Host_TS_*).
//! - [`distribution`] — the fleet tier's incremental (xDS-style) directive
//!   distribution vocabulary: versioned [`DirectiveBatch`] deltas, host
//!   [`DirectiveAck`]s, and the sender-side [`DeltaDistributor`].

pub mod adaptive;
pub mod arcus;
pub mod baseline;
pub mod control;
pub mod distribution;

pub use adaptive::{AdaptiveConfig, AdaptiveControlPlane};
pub use arcus::ArcusControlPlane;
pub use baseline::{NoOpControlPlane, StaticRateControlPlane};
pub use control::{
    Admitted, ApiError, ControlPlane, Directive, DirectiveKind, FlowStatusView, ObsView,
    RegisterRequest, ShaperProgram, TickContext,
};
pub use distribution::{DeltaDistributor, DirectiveAck, DirectiveBatch};
