//! PerFlowStatusTable (§4.3): the dynamic flow registry.
//!
//! "Each entry includes … the VM ID, path ID and accelerator ID for this
//! flow, per-flow SLO, the mechanism parameters configured for this flow,
//! and the current SLO status measured from hardware counters."

use crate::flow::{FlowId, Path, Slo};
use crate::shaping::TokenBucketParams;
use crate::util::units::{Rate, Time};

/// Measured hardware-counter window for one flow.
#[derive(Debug, Clone, Copy, Default)]
pub struct MeasuredWindow {
    /// Window span.
    pub span: Time,
    /// Bytes completed in the window.
    pub bytes: u64,
    /// Operations completed in the window.
    pub ops: u64,
    /// 99th-percentile latency in the window (ps), if tracked.
    pub p99_latency: Option<u64>,
}

impl MeasuredWindow {
    pub fn throughput(&self) -> Rate {
        if self.span == 0 {
            Rate::ZERO
        } else {
            crate::util::units::throughput(self.bytes, self.span)
        }
    }
    pub fn iops(&self) -> f64 {
        if self.span == 0 {
            0.0
        } else {
            self.ops as f64 * crate::util::units::SECONDS as f64 / self.span as f64
        }
    }
}

/// Current SLO standing of a flow.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SloState {
    /// No full measurement window yet.
    Warmup,
    Meeting,
    Violating,
}

/// One PerFlowStatusTable row.
#[derive(Debug, Clone)]
pub struct FlowStatus {
    pub flow: FlowId,
    pub vm: usize,
    pub path: Path,
    pub accel: usize,
    pub accel_name: String,
    pub slo: Slo,
    /// Message size this flow predominantly uses (profiling context key).
    pub size_hint: u64,
    /// Mechanism parameters currently programmed into the flow's shaper.
    pub params: Option<TokenBucketParams>,
    /// Shaping rate currently programmed (units/sec).
    pub shaped_rate: Option<f64>,
    /// Latest measured window.
    pub measured: MeasuredWindow,
    pub state: SloState,
    /// Consecutive violating windows (hysteresis for reshape decisions).
    pub violations: u32,
    /// Total reconfigurations applied (reporting).
    pub reconfigs: u32,
}

impl FlowStatus {
    pub fn new(
        flow: FlowId,
        vm: usize,
        path: Path,
        accel: usize,
        accel_name: &str,
        slo: Slo,
        size_hint: u64,
    ) -> Self {
        FlowStatus {
            flow,
            vm,
            path,
            accel,
            accel_name: accel_name.to_string(),
            slo,
            size_hint,
            params: None,
            shaped_rate: None,
            measured: MeasuredWindow::default(),
            state: SloState::Warmup,
            violations: 0,
            reconfigs: 0,
        }
    }

    /// Evaluate the SLO against the measured window (Algorithm 1's
    /// `SLOViolationChecker`: "ReadSLOPerfCnts[FlowID] < target[FlowID]").
    /// A small tolerance keeps the checker from flapping on quantization.
    pub fn check(&self) -> SloState {
        const TOL: f64 = 0.02;
        if self.measured.span == 0 {
            return SloState::Warmup;
        }
        let ok = match self.slo {
            Slo::Throughput { target, .. } => {
                self.measured.throughput().0 >= target.0 * (1.0 - TOL)
            }
            Slo::Iops { target, .. } => self.measured.iops() >= target * (1.0 - TOL),
            Slo::Latency { max_ps, .. } => match self.measured.p99_latency {
                Some(p99) => p99 <= max_ps,
                None => true,
            },
            Slo::BestEffort => true,
        };
        if ok {
            SloState::Meeting
        } else {
            SloState::Violating
        }
    }
}

/// The table: rows in registration order plus an id → row index so the
/// per-flow lookups the control loop performs every tick (and every
/// registration at 10k-flow scale) stay O(1) instead of O(flows).
/// Iteration order — which planner decisions and directive emission follow
/// — remains registration order, exactly as before the index existed.
#[derive(Debug, Clone, Default)]
pub struct PerFlowStatusTable {
    rows: Vec<FlowStatus>,
    /// FlowId → index into `rows` (never iterated: map order is unused).
    index: std::collections::HashMap<FlowId, usize>,
}

impl PerFlowStatusTable {
    pub fn register(&mut self, status: FlowStatus) -> FlowId {
        let id = status.flow;
        debug_assert!(!self.index.contains_key(&id), "duplicate flow {id}");
        self.index.insert(id, self.rows.len());
        self.rows.push(status);
        id
    }

    pub fn deregister(&mut self, flow: FlowId) -> Option<FlowStatus> {
        let idx = self.index.remove(&flow)?;
        let row = self.rows.remove(idx);
        // Rows after the removal slot shifted down one.
        for r in &self.rows[idx..] {
            if let Some(i) = self.index.get_mut(&r.flow) {
                *i -= 1;
            }
        }
        Some(row)
    }

    pub fn get(&self, flow: FlowId) -> Option<&FlowStatus> {
        self.index.get(&flow).map(|&i| &self.rows[i])
    }
    pub fn get_mut(&mut self, flow: FlowId) -> Option<&mut FlowStatus> {
        self.index.get(&flow).map(|&i| &mut self.rows[i])
    }

    pub fn iter(&self) -> impl Iterator<Item = &FlowStatus> {
        self.rows.iter()
    }
    pub fn iter_mut(&mut self) -> impl Iterator<Item = &mut FlowStatus> {
        self.rows.iter_mut()
    }
    pub fn len(&self) -> usize {
        self.rows.len()
    }
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Flows sharing an accelerator (capacity-planning denominator).
    pub fn flows_on_accel(&self, accel: usize) -> Vec<&FlowStatus> {
        self.rows.iter().filter(|r| r.accel == accel).collect()
    }

    /// Number of flows sharing an accelerator — the allocation-free
    /// counterpart of [`Self::flows_on_accel`] for paths that only need
    /// the count (10k-flow registration storms call this per flow).
    pub fn count_on_accel(&self, accel: usize) -> usize {
        self.rows.iter().filter(|r| r.accel == accel).count()
    }

    /// Sum of required shaping rates (units/s) already committed on an
    /// accelerator — Scenario 1's "how much available capacity is left".
    pub fn committed_rate(&self, accel: usize) -> f64 {
        self.rows
            .iter()
            .filter(|r| r.accel == accel)
            .filter_map(|r| r.slo.required_rate().map(|(rate, _)| rate))
            .sum()
    }

    /// Update a flow's measured window and its SLO state; returns the new
    /// state.
    pub fn record_window(&mut self, flow: FlowId, w: MeasuredWindow) -> Option<SloState> {
        let row = self.get_mut(flow)?;
        row.measured = w;
        let state = row.check();
        match state {
            SloState::Violating => row.violations += 1,
            SloState::Meeting => row.violations = 0,
            SloState::Warmup => {}
        }
        row.state = state;
        Some(state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::{Rate, MICROS, MILLIS};

    fn status(flow: FlowId, accel: usize, slo: Slo) -> FlowStatus {
        FlowStatus::new(flow, flow, Path::FunctionCall, accel, "ipsec", slo, 1500)
    }

    #[test]
    fn register_lookup_deregister() {
        let mut t = PerFlowStatusTable::default();
        t.register(status(0, 0, Slo::gbps(10.0)));
        t.register(status(1, 0, Slo::gbps(20.0)));
        assert_eq!(t.len(), 2);
        assert_eq!(t.get(1).unwrap().vm, 1);
        assert!(t.deregister(0).is_some());
        assert!(t.get(0).is_none());
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn committed_rate_sums_per_accel() {
        let mut t = PerFlowStatusTable::default();
        t.register(status(0, 0, Slo::gbps(10.0)));
        t.register(status(1, 0, Slo::gbps(20.0)));
        t.register(status(2, 1, Slo::gbps(40.0)));
        t.register(status(3, 0, Slo::BestEffort)); // no commitment
        let bytes_per_sec = t.committed_rate(0);
        assert!((bytes_per_sec - 30e9 / 8.0).abs() < 1.0);
        assert_eq!(t.flows_on_accel(0).len(), 3);
    }

    #[test]
    fn throughput_slo_check() {
        let mut s = status(0, 0, Slo::gbps(10.0));
        assert_eq!(s.check(), SloState::Warmup);
        // 10 Gbps over 1 ms = 1.25 MB.
        s.measured = MeasuredWindow {
            span: MILLIS,
            bytes: 1_250_000,
            ops: 800,
            p99_latency: None,
        };
        assert_eq!(s.check(), SloState::Meeting);
        s.measured.bytes = 900_000;
        assert_eq!(s.check(), SloState::Violating);
    }

    #[test]
    fn latency_slo_check() {
        let mut s = status(
            0,
            0,
            Slo::Latency {
                max_ps: MICROS,
                percentile: 99.0,
            },
        );
        s.measured = MeasuredWindow {
            span: MILLIS,
            bytes: 0,
            ops: 100,
            p99_latency: Some(MICROS / 2),
        };
        assert_eq!(s.check(), SloState::Meeting);
        s.measured.p99_latency = Some(2 * MICROS);
        assert_eq!(s.check(), SloState::Violating);
    }

    #[test]
    fn violations_count_with_hysteresis() {
        let mut t = PerFlowStatusTable::default();
        t.register(status(0, 0, Slo::gbps(10.0)));
        let bad = MeasuredWindow {
            span: MILLIS,
            bytes: 100_000,
            ops: 10,
            p99_latency: None,
        };
        let good = MeasuredWindow {
            span: MILLIS,
            bytes: 2_000_000,
            ops: 10,
            p99_latency: None,
        };
        assert_eq!(t.record_window(0, bad), Some(SloState::Violating));
        assert_eq!(t.record_window(0, bad), Some(SloState::Violating));
        assert_eq!(t.get(0).unwrap().violations, 2);
        assert_eq!(t.record_window(0, good), Some(SloState::Meeting));
        assert_eq!(t.get(0).unwrap().violations, 0);
    }

    #[test]
    fn best_effort_never_violates() {
        let mut s = status(0, 0, Slo::BestEffort);
        s.measured = MeasuredWindow {
            span: MILLIS,
            bytes: 0,
            ops: 0,
            p99_latency: Some(u64::MAX),
        };
        assert_eq!(s.check(), SloState::Meeting);
    }
}
