//! The Arcus control plane (§4.3): SLO management runtime.
//!
//! This is the paper's software half. It owns three data structures —
//!
//! - [`profile::ProfileTable`] — offline-learned `Capacity(t, X, N)` over
//!   traffic-pattern and path combinations, each entry tagged SLO-Friendly
//!   or SLO-Violating;
//! - an `AccTable` ([`profile::AccTable`]) mapping accelerators to their
//!   available paths;
//! - [`status::PerFlowStatusTable`] — the dynamic per-flow registry
//!   (VM/path/accelerator ids, SLO, configured mechanism parameters,
//!   measured SLO status);
//!
//! — and runs Algorithm 1 periodically ([`planner::run_tick`]): check each
//! flow's SLO from hardware counters, re-adjust (path selection + reshape
//! decision) on violation, and admit/reject new registrations via capacity
//! planning. Decisions come back as [`planner::Action`]s; the enclosing
//! system (simulator or serving runtime) applies them to the shapers with
//! the measured ~10 µs reconfiguration latency.

pub mod planner;
pub mod profile;
pub mod status;

pub use planner::{run_tick, Action, PlannerConfig, RejectReason};
pub use profile::{AccTable, ProfileKey, ProfileTable};
pub use status::{FlowStatus, MeasuredWindow, PerFlowStatusTable, SloState};
