//! Offline profiling: the `Capacity(t, X, N)` table and the AccTable.
//!
//! §3.3: "perform offline profiling to learn Capacity(t, X, N), the
//! available capacity of an accelerator X at a given time shared by N VMs,
//! w.r.t. traffic patterns T, path mode combinations P, and system settings
//! S (e.g. PCIe bandwidth). We store this as a table for the control plane
//! to make online decisions."
//!
//! Entries are keyed on (accelerator, path, message-size bucket, flow-count
//! bucket) and record the sustainable ingress capacity of that context: the
//! minimum of the accelerator's curve-derived throughput at that size and
//! the communication budget of the path (per-direction PCIe bandwidth net of
//! TLP overheads and the egress-ratio R feedback — a compressor's egress is
//! cheap, a decompressor's is expensive, SHA's is free). Each entry carries
//! the 1-bit SLO-Friendly tag of §4.3.
//!
//! Learning is analytic over the device models here (`learn`), and can be
//! refined by measurement (`observe`) — the control plane treats both the
//! same way, exactly like the paper's table of "profiled results".

use crate::accel::{AccelModel, Egress};
use crate::flow::Path;
use crate::pcie::fabric::FabricConfig;
use crate::pcie::link::Dir;
use crate::util::units::Rate;
use std::collections::HashMap;

/// Size buckets used by the table (powers of four-ish around the paper's
/// sweep points).
pub const SIZE_BUCKETS: [u64; 9] = [64, 128, 256, 1024, 1500, 4096, 16384, 65536, 524288];

/// Bucket a message size to the nearest profiled size.
pub fn size_bucket(bytes: u64) -> u64 {
    *SIZE_BUCKETS
        .iter()
        .min_by_key(|&&b| (b as i64 - bytes as i64).unsigned_abs())
        .unwrap()
}

/// Flow-count buckets (1, 2, 4, 8, 16 — Fig 7b's sweep).
pub const FLOW_BUCKETS: [usize; 5] = [1, 2, 4, 8, 16];

pub fn flow_bucket(n: usize) -> usize {
    *FLOW_BUCKETS
        .iter()
        .min_by_key(|&&b| (b as i64 - n as i64).unsigned_abs())
        .unwrap()
}

/// Table key: one profiled context.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ProfileKey {
    pub accel: String,
    pub path: Path,
    pub size: u64,
    pub n_flows: usize,
}

/// One profiled context's learned capacity.
#[derive(Debug, Clone, Copy)]
pub struct ProfileEntry {
    /// Sustainable aggregate ingress rate in this context.
    pub capacity: Rate,
    /// Which resource binds: useful for path selection.
    pub bound_by: Bound,
    /// §4.3's 1-bit tag: can SLOs be met in this context at all, or does
    /// the pattern mixture inherently violate (e.g. tiny-message mixtures
    /// that crater the engine below any reasonable SLO sum)?
    pub slo_friendly: bool,
}

/// The binding resource for a context.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    Accelerator,
    PcieUp,
    PcieDown,
}

/// AccTable (§4.3): which paths can reach each accelerator.
#[derive(Debug, Clone, Default)]
pub struct AccTable {
    entries: HashMap<String, Vec<Path>>,
}

impl AccTable {
    pub fn register(&mut self, accel: &str, paths: Vec<Path>) {
        self.entries.insert(accel.to_string(), paths);
    }
    pub fn paths(&self, accel: &str) -> &[Path] {
        self.entries.get(accel).map(|v| v.as_slice()).unwrap_or(&[])
    }
}

/// The Capacity(t, X, N) table.
#[derive(Debug, Clone, Default)]
pub struct ProfileTable {
    entries: HashMap<ProfileKey, ProfileEntry>,
}

/// Fraction of the engine's MTU-size effective rate below which a context
/// is tagged SLO-Violating: pattern mixtures that hold the engine under
/// this can't honor meaningful SLO sums and should be strictly avoided by
/// the control plane (§4.3's 1-bit tag).
const FRIENDLY_EFFICIENCY: f64 = 0.30;

impl ProfileTable {
    /// Analytically learn the table for a set of accelerator models on a
    /// PCIe fabric. Covers every (accel, path, size-bucket, flow-bucket).
    pub fn learn(models: &[AccelModel], fabric: &FabricConfig) -> Self {
        let mut t = ProfileTable::default();
        for m in models {
            for &path in &Path::ALL {
                for &size in &SIZE_BUCKETS {
                    for &n in &FLOW_BUCKETS {
                        let key = ProfileKey {
                            accel: m.name.to_string(),
                            path,
                            size,
                            n_flows: n,
                        };
                        t.entries.insert(key, Self::derive(m, fabric, path, size, n));
                    }
                }
            }
        }
        t
    }

    /// Capacity of one context = min(engine rate at size, path comm budget).
    fn derive(
        m: &AccelModel,
        fabric: &FabricConfig,
        path: Path,
        size: u64,
        n_flows: usize,
    ) -> ProfileEntry {
        // Engine-side: sustained ingress rate at this message size,
        // including per-message setup (amortized). Multi-flow sharing of a
        // single engine costs a small context-switch-like overhead per flow
        // beyond 1 (measured in Fig 7b as slightly sub-linear scaling).
        let per_msg = m.base_service_time(size) as f64;
        let flow_penalty = 1.0 + 0.004 * (n_flows.saturating_sub(1)) as f64;
        let engine = Rate(size as f64 * 8.0 / (per_msg * flow_penalty) * 1e12);

        // Communication side: per-direction payload bandwidth at this
        // message size — wire efficiency AND the root-complex TLP-rate
        // ceiling (64 B messages collapse here, not on the wire).
        let net = fabric.link.effective_payload_rate(size).as_bits_per_sec();
        let r = match m.egress {
            Egress::Ratio(r) => r,
            Egress::Fixed(out) => out as f64 / size as f64,
        };
        // Direction load per unit of ingress, by path (see DESIGN.md):
        //   FunctionCall: ingress rides Down (read completions), egress Up.
        //   InlineNicRx:  ingress from the wire, egress DMA-writes Up.
        //   InlineNicTx:  ingress DMA-reads Down, egress to the wire.
        //   InlineP2p:    ingress Down (from host buffers), egress Up (NVMe).
        let (down_per_in, up_per_in) = match path {
            Path::FunctionCall => (1.0, r),
            Path::InlineNicRx => (0.0, r),
            Path::InlineNicTx => (1.0, 0.0),
            Path::InlineP2p => (1.0, r),
        };
        let down_cap = if down_per_in > 0.0 {
            net / down_per_in
        } else {
            f64::INFINITY
        };
        let up_cap = if up_per_in > 0.0 {
            net / up_per_in
        } else {
            f64::INFINITY
        };

        let (capacity, bound_by) = {
            let mut best = (engine.0, Bound::Accelerator);
            if down_cap < best.0 {
                best = (down_cap, Bound::PcieDown);
            }
            if up_cap < best.0 {
                best = (up_cap, Bound::PcieUp);
            }
            best
        };
        // Friendliness is relative to what the engine sustains at MTU —
        // the paper's "full load, MTU-sized packets" reference point.
        let mtu_rate = m.effective_rate(crate::util::units::MTU).0.max(1.0);
        ProfileEntry {
            capacity: Rate(capacity),
            bound_by,
            slo_friendly: engine.0 / mtu_rate >= FRIENDLY_EFFICIENCY,
        }
    }

    /// Refine an entry from a measured run (the paper re-runs classification
    /// "every time a new flow is registered").
    pub fn observe(&mut self, key: ProfileKey, measured: Rate, friendly: bool) {
        let bound = self
            .entries
            .get(&key)
            .map(|e| e.bound_by)
            .unwrap_or(Bound::Accelerator);
        self.entries.insert(
            key,
            ProfileEntry {
                capacity: measured,
                bound_by: bound,
                slo_friendly: friendly,
            },
        );
    }

    /// Scale every entry of one accelerator's capacity by `factor` —
    /// fault injection's profile mis-estimation ([`crate::faults`]): the
    /// control plane plans against the scaled table while the hardware
    /// keeps its true rates. SLO-friendly tags are left alone (the skew
    /// mis-states magnitude, not class).
    pub fn scale_accel(&mut self, accel: &str, factor: f64) {
        for (k, e) in self.entries.iter_mut() {
            if k.accel == accel {
                e.capacity = Rate(e.capacity.0 * factor);
            }
        }
    }

    /// Look up the capacity for a context (bucketing size and flow count).
    pub fn capacity(&self, accel: &str, path: Path, size: u64, n_flows: usize) -> Option<ProfileEntry> {
        self.entries
            .get(&ProfileKey {
                accel: accel.to_string(),
                path,
                size: size_bucket(size),
                n_flows: flow_bucket(n_flows),
            })
            .copied()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// All profiled entries for an accelerator, for reports (Fig 7a/7c).
    pub fn entries_for(&self, accel: &str) -> Vec<(&ProfileKey, &ProfileEntry)> {
        let mut v: Vec<_> = self
            .entries
            .iter()
            .filter(|(k, _)| k.accel == accel)
            .collect();
        v.sort_by_key(|(k, _)| (k.path.name(), k.size, k.n_flows));
        v
    }
}

/// Direction utilization helper used by path selection: which PCIe direction
/// does a path's ingress ride on?
pub fn ingress_dir(path: Path) -> Option<Dir> {
    match path {
        Path::FunctionCall | Path::InlineNicTx | Path::InlineP2p => Some(Dir::Down),
        Path::InlineNicRx => None, // from the wire
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> ProfileTable {
        ProfileTable::learn(
            &[
                AccelModel::ipsec_32g(),
                AccelModel::sha3_512(),
                AccelModel::compress(),
                AccelModel::decompress(),
            ],
            &FabricConfig::gen3_x8(),
        )
    }

    #[test]
    fn covers_full_grid() {
        let t = table();
        assert_eq!(
            t.len(),
            4 * Path::ALL.len() * SIZE_BUCKETS.len() * FLOW_BUCKETS.len()
        );
    }

    #[test]
    fn small_messages_tagged_violating() {
        let t = table();
        let tiny = t.capacity("ipsec", Path::FunctionCall, 64, 2).unwrap();
        let big = t.capacity("ipsec", Path::FunctionCall, 4096, 2).unwrap();
        assert!(!tiny.slo_friendly, "64B ipsec should be SLO-violating");
        assert!(big.slo_friendly);
        assert!(big.capacity.0 > 3.0 * tiny.capacity.0);
    }

    #[test]
    fn sha3_never_egress_bound() {
        // SHA-3-512's 64 B fixed output cannot bind the Up direction.
        let t = table();
        for &size in &SIZE_BUCKETS {
            let e = t.capacity("sha3_512", Path::InlineNicRx, size, 1).unwrap();
            assert_ne!(e.bound_by, Bound::PcieUp, "size={size}");
        }
    }

    #[test]
    fn decompress_egress_binds_at_large_sizes() {
        // R=2.2: pushing X in costs 2.2X out — the Up direction saturates
        // before the engine at large sizes on write-heavy paths.
        let t = table();
        let e = t
            .capacity("decompress", Path::InlineNicRx, 65536, 1)
            .unwrap();
        assert_eq!(e.bound_by, Bound::PcieUp);
        // Required PCIe egress for X Gbps of decompression SLO is 2.2X —
        // the §5.3.1 observation, inverted for decompression.
        assert!(e.capacity.as_gbps() < 30.0);
    }

    #[test]
    fn compression_needs_more_ingress_than_slo() {
        // §5.3.1: "allocating X Gbps PCIe bandwidth is not sufficient to
        // feed a compression accelerator where SLO = X Gbps" — ingress is
        // the bottleneck dimension; capacity reflects ingress feed rate.
        let t = table();
        let e = t.capacity("compress", Path::FunctionCall, 16384, 1).unwrap();
        // Engine-bound at 16 Gbps peak × curve, not egress-bound.
        assert_ne!(e.bound_by, Bound::PcieUp);
    }

    #[test]
    fn capacity_bucketing_uses_nearest() {
        let t = table();
        let a = t.capacity("ipsec", Path::FunctionCall, 1400, 2).unwrap();
        let b = t.capacity("ipsec", Path::FunctionCall, 1500, 2).unwrap();
        assert_eq!(a.capacity.0, b.capacity.0);
        assert_eq!(size_bucket(90), 64);
        assert_eq!(size_bucket(104), 128);
        assert_eq!(size_bucket(200), 256);
        assert_eq!(flow_bucket(3), 2); // nearest of [1,2,4,8,16] — ties to 2
    }

    #[test]
    fn unknown_accelerator_key_returns_none() {
        let t = table();
        assert!(t
            .capacity("no_such_engine", Path::FunctionCall, 1500, 2)
            .is_none());
        // Known accelerator, but an empty table has nothing either.
        let empty = ProfileTable::default();
        assert!(empty.capacity("ipsec", Path::FunctionCall, 1500, 2).is_none());
        assert!(empty.is_empty());
    }

    #[test]
    fn lookups_beyond_profiled_range_clamp_to_largest_bucket() {
        let t = table();
        // Flow counts past the largest profiled bucket (16) clamp to it.
        assert_eq!(flow_bucket(100), 16);
        let at16 = t.capacity("ipsec", Path::FunctionCall, 1500, 16).unwrap();
        let at100 = t.capacity("ipsec", Path::FunctionCall, 1500, 100).unwrap();
        assert_eq!(at16.capacity.0, at100.capacity.0);
        // Sizes past the largest profiled bucket (512 KB) clamp likewise.
        assert_eq!(size_bucket(64 << 20), 524288);
        let huge = t
            .capacity("ipsec", Path::FunctionCall, 64 << 20, 2)
            .unwrap();
        let max_bucket = t.capacity("ipsec", Path::FunctionCall, 524288, 2).unwrap();
        assert_eq!(huge.capacity.0, max_bucket.capacity.0);
        // And zero-size lookups clamp down to the smallest bucket.
        assert_eq!(size_bucket(0), 64);
        assert!(t.capacity("ipsec", Path::FunctionCall, 0, 1).is_some());
    }

    #[test]
    fn slo_friendly_boundary_exactly_at_threshold() {
        // The 1-bit tag flips where the engine's rate at the profiled size
        // falls below FRIENDLY_EFFICIENCY of its MTU rate. A measured
        // `observe` exactly at a context's capacity must keep whatever tag
        // the observer supplies — the boundary case the control plane acts
        // on when a context sits exactly at the committed sum.
        let mut t = table();
        let key = ProfileKey {
            accel: "ipsec".into(),
            path: Path::FunctionCall,
            size: 1500,
            n_flows: 2,
        };
        let learned = t.capacity("ipsec", Path::FunctionCall, 1500, 2).unwrap();
        // Re-observing the exact same capacity, flipped to SLO-Violating:
        // lookups must now report unfriendly at unchanged capacity.
        t.observe(key.clone(), learned.capacity, false);
        let e = t.capacity("ipsec", Path::FunctionCall, 1500, 2).unwrap();
        assert_eq!(e.capacity.0, learned.capacity.0);
        assert!(!e.slo_friendly);
        // Flip back friendly at the same capacity.
        t.observe(key, learned.capacity, true);
        assert!(t
            .capacity("ipsec", Path::FunctionCall, 1500, 2)
            .unwrap()
            .slo_friendly);
    }

    #[test]
    fn observe_overrides_analytic() {
        let mut t = table();
        let key = ProfileKey {
            accel: "ipsec".into(),
            path: Path::FunctionCall,
            size: 1500,
            n_flows: 2,
        };
        t.observe(key.clone(), Rate::gbps(5.0), false);
        let e = t.capacity("ipsec", Path::FunctionCall, 1500, 2).unwrap();
        assert!((e.capacity.as_gbps() - 5.0).abs() < 1e-9);
        assert!(!e.slo_friendly);
    }

    #[test]
    fn acctable_paths() {
        let mut at = AccTable::default();
        at.register("ipsec", vec![Path::FunctionCall, Path::InlineNicRx]);
        assert_eq!(at.paths("ipsec").len(), 2);
        assert!(at.paths("unknown").is_empty());
    }

    #[test]
    fn more_flows_slightly_reduce_capacity() {
        let t = table();
        let one = t.capacity("ipsec", Path::FunctionCall, 1500, 1).unwrap();
        let sixteen = t.capacity("ipsec", Path::FunctionCall, 1500, 16).unwrap();
        assert!(sixteen.capacity.0 < one.capacity.0);
        assert!(sixteen.capacity.0 > 0.9 * one.capacity.0); // near-full at 16 (Fig 7b)
    }
}
