//! Algorithm 1 — the Arcus runtime: capacity planning, admission control,
//! path selection, and reshape decisions.
//!
//! The planner is pure: it reads the [`ProfileTable`] and
//! [`PerFlowStatusTable`] and emits [`Action`]s; the enclosing system
//! applies them to the hardware (token-bucket registers, path routing) with
//! the measured reconfiguration latency. Keeping it side-effect-free makes
//! the control plane unit-testable and lets both the simulator and the
//! wall-clock serving runtime share it.

use super::profile::{AccTable, ProfileTable};
use super::status::{PerFlowStatusTable, SloState};
use crate::flow::{FlowId, Path, Slo};
use crate::shaping::{ShapeMode, TokenBucketParams};

/// Planner tuning.
#[derive(Debug, Clone, Copy)]
pub struct PlannerConfig {
    /// Violating windows required before reshaping (hysteresis).
    pub reshape_after: u32,
    /// Multiplicative step when compensating an under-attaining flow.
    pub boost_step: f64,
    /// Hard cap on over-provisioning relative to the SLO (keeps one flow's
    /// compensation from stealing the accelerator).
    pub max_boost: f64,
    /// Headroom the admission controller reserves (fraction of capacity it
    /// refuses to commit).
    pub admission_headroom: f64,
    /// Shaping-rate headroom over the SLO: buckets are programmed slightly
    /// above the target so sampling effects still *measure* at the SLO.
    pub shaping_headroom: f64,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            reshape_after: 2,
            boost_step: 1.05,
            max_boost: 1.30,
            admission_headroom: 0.05,
            shaping_headroom: 1.01,
        }
    }
}

/// Decisions emitted by one planner tick.
#[derive(Debug, Clone, PartialEq)]
pub enum Action {
    /// Reprogram a flow's token bucket to a new rate (units/sec, with the
    /// derived register values).
    Reshape {
        flow: FlowId,
        rate: f64,
        params: TokenBucketParams,
    },
    /// Move a flow to a less-contended path (Scenario 3 with PathSelection).
    SwitchPath { flow: FlowId, to: Path },
}

/// Why admission control (or renegotiation) refused an SLO. Typed so
/// callers — the adaptive plane, a tenant SDK, the renegotiation path —
/// can react to the *category* (transient capacity pressure vs structural
/// impossibility) without parsing strings; `Display` renders the human
/// text the old stringly errors carried.
#[derive(Debug, Clone, PartialEq)]
pub enum RejectReason {
    /// Committed SLOs plus this one exceed the profiled budget. Transient:
    /// capacity may free up when a flow departs or renegotiates down.
    CapacityExceeded {
        /// Admission budget (bytes/sec, net of headroom) in this context.
        budget: f64,
        /// SLO rates already committed on the engine (bytes/sec).
        committed: f64,
        /// The rate this request asked to commit (bytes/sec).
        requested: f64,
    },
    /// The profile table holds no entry for this (accel, path) context.
    /// Structural: retrying the identical request changes nothing.
    UnprofiledContext {
        /// Accelerator model name.
        accel: String,
        /// Invocation path that has no profile.
        path: Path,
    },
    /// The profiled context is tagged SLO-Violating (e.g. tiny messages
    /// that thrash the engine). Structural for this context.
    SloViolatingContext {
        /// Accelerator model name.
        accel: String,
        /// Message-size context key (bytes).
        size: u64,
        /// Flow count the context was profiled at.
        n_flows: usize,
    },
    /// Renegotiation named a flow that is not registered.
    UnknownFlow {
        /// The unregistered flow id.
        flow: FlowId,
    },
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RejectReason::CapacityExceeded { budget, committed, requested } => write!(
                f,
                "capacity {budget:.3e} B/s, committed {committed:.3e}, requested {requested:.3e}"
            ),
            RejectReason::UnprofiledContext { accel, path } => {
                write!(f, "no profile for {accel} on {}", path.name())
            }
            RejectReason::SloViolatingContext { accel, size, n_flows } => write!(
                f,
                "context tagged SLO-Violating ({accel}, {size}B, {n_flows} flows)"
            ),
            RejectReason::UnknownFlow { flow } => write!(f, "flow {flow} is not registered"),
        }
    }
}

/// Admission-control verdict for a new registration.
#[derive(Debug, Clone, PartialEq)]
pub enum Admission {
    /// Accepted, with the initial shaping parameters to program.
    Accept {
        rate: f64,
        params: TokenBucketParams,
    },
    /// Rejected: committed SLOs plus this one exceed profiled capacity.
    Reject { reason: RejectReason },
}

/// CapacityPlanning(CHECK) + AdmissionControl (Algorithm 1 lines 7–10,
/// 14–16; Scenarios 1 & 2): admit iff the accelerator's profiled capacity
/// in this flow's context covers all committed SLO rates plus the new one.
/// Sum of committed rates on an accelerator, normalized to bytes/sec
/// (IOPS commitments convert via each flow's message-size hint).
pub fn committed_bytes_per_sec(status: &PerFlowStatusTable, accel: usize) -> f64 {
    status
        .flows_on_accel(accel)
        .iter()
        .filter_map(|r| {
            r.slo.required_rate().map(|(rate, mode)| match mode {
                ShapeMode::Gbps => rate,
                ShapeMode::Iops => rate * r.size_hint as f64,
            })
        })
        .sum()
}

#[allow(clippy::too_many_arguments)]
pub fn admission_control(
    cfg: &PlannerConfig,
    profile: &ProfileTable,
    status: &PerFlowStatusTable,
    accel: usize,
    accel_name: &str,
    path: Path,
    size_hint: u64,
    slo: &Slo,
) -> Admission {
    let n_after = status.flows_on_accel(accel).len() + 1;
    capacity_check(
        cfg, profile, status, accel, accel_name, path, size_hint, slo, n_after, None,
    )
}

/// SLO renegotiation (Scenario 2): the same CHECK for an *already
/// registered* flow — the flow count on the accelerator is unchanged, and
/// the committed sum excludes the flow's own current commitment (the new
/// rate replaces it rather than stacking on top). Accepting returns the
/// fresh shaping parameters; rejecting leaves the old contract in force
/// (callers must not mutate the table on rejection).
pub fn renegotiation_control(
    cfg: &PlannerConfig,
    profile: &ProfileTable,
    status: &PerFlowStatusTable,
    flow: FlowId,
    new_slo: &Slo,
) -> Admission {
    let Some(row) = status.get(flow) else {
        return Admission::Reject {
            reason: RejectReason::UnknownFlow { flow },
        };
    };
    let n = status.flows_on_accel(row.accel).len();
    capacity_check(
        cfg,
        profile,
        status,
        row.accel,
        &row.accel_name,
        row.path,
        row.size_hint,
        new_slo,
        n,
        Some(flow),
    )
}

/// The one CapacityPlanning CHECK both entry points share: can `slo` be
/// committed for a flow in context `(accel_name, path, size_hint)` with `n`
/// flows sharing the engine? `exclude` names a flow whose current
/// commitment is replaced rather than added (renegotiation); `None` means
/// a new registration (the candidate is not yet in the table).
#[allow(clippy::too_many_arguments)]
fn capacity_check(
    cfg: &PlannerConfig,
    profile: &ProfileTable,
    status: &PerFlowStatusTable,
    accel: usize,
    accel_name: &str,
    path: Path,
    size_hint: u64,
    slo: &Slo,
    n: usize,
    exclude: Option<FlowId>,
) -> Admission {
    let Some((rate, mode)) = slo.required_rate() else {
        // Best-effort / latency flows take no committed bandwidth; they are
        // always admitted and shaped opportunistically.
        return Admission::Accept {
            rate: 0.0,
            params: TokenBucketParams::for_rate(1.0, ShapeMode::Iops),
        };
    };
    let entry = match profile.capacity(accel_name, path, size_hint, n) {
        Some(e) => e,
        None => {
            return Admission::Reject {
                reason: RejectReason::UnprofiledContext {
                    accel: accel_name.to_string(),
                    path,
                },
            }
        }
    };
    if !entry.slo_friendly {
        return Admission::Reject {
            reason: RejectReason::SloViolatingContext {
                accel: accel_name.to_string(),
                size: size_hint,
                n_flows: n,
            },
        };
    }
    // The binding capacity is the TIGHTEST context among every committed
    // flow's (size, path) and the new one — a later large-message flow must
    // not overcommit an engine already constrained by a small-message
    // tenant (Scenario 1's availability check over the whole mixture).
    let mut capacity_bytes = entry.capacity.as_bits_per_sec() / 8.0;
    for r in status.flows_on_accel(accel) {
        if Some(r.flow) == exclude || r.slo.required_rate().is_none() {
            continue;
        }
        if let Some(e) = profile.capacity(accel_name, r.path, r.size_hint, n) {
            capacity_bytes = capacity_bytes.min(e.capacity.as_bits_per_sec() / 8.0);
        }
    }
    let rate_bytes = match mode {
        ShapeMode::Gbps => rate,
        ShapeMode::Iops => rate * size_hint as f64,
    };
    let excluded_bytes = exclude
        .and_then(|f| status.get(f))
        .and_then(|r| {
            r.slo.required_rate().map(|(own, m)| match m {
                ShapeMode::Gbps => own,
                ShapeMode::Iops => own * r.size_hint as f64,
            })
        })
        .unwrap_or(0.0);
    let committed = committed_bytes_per_sec(status, accel) - excluded_bytes;
    let budget = capacity_bytes * (1.0 - cfg.admission_headroom);
    if committed + rate_bytes > budget {
        return Admission::Reject {
            reason: RejectReason::CapacityExceeded {
                budget,
                committed,
                requested: rate_bytes,
            },
        };
    }
    Admission::Accept {
        rate,
        params: TokenBucketParams::for_rate(rate, mode),
    }
}

/// ReshapeDecision (Algorithm 1 line 20): compute a corrected shaping rate
/// for a violating flow. The controller is multiplicative-increase toward
/// the SLO, bounded by `max_boost` and by the flow's fair share of profiled
/// capacity — the decoupling insight: we adjust the *fetch* pattern, never
/// asking the VM to change its submission pattern.
pub fn reshape_decision(
    cfg: &PlannerConfig,
    profile: &ProfileTable,
    status: &PerFlowStatusTable,
    flow: FlowId,
) -> Option<Action> {
    let row = status.get(flow)?;
    let (slo_rate, mode) = row.slo.required_rate()?;
    let current = row.shaped_rate.unwrap_or(slo_rate);
    let measured = match mode {
        ShapeMode::Gbps => row.measured.throughput().as_bits_per_sec() / 8.0,
        ShapeMode::Iops => row.measured.iops(),
    };
    if measured <= 0.0 {
        return None;
    }
    // Under-attainment ratio drives the correction.
    let deficit = slo_rate / measured;
    let mut new_rate = (current * deficit.min(cfg.boost_step.powi(2)))
        .max(current * cfg.boost_step);
    // Cap: never boost past max_boost × SLO, never past the flow's share of
    // the profiled context capacity.
    new_rate = new_rate.min(slo_rate * cfg.max_boost);
    if let Some(entry) = profile.capacity(
        &row.accel_name,
        row.path,
        row.size_hint,
        status.flows_on_accel(row.accel).len(),
    ) {
        let cap_units = match mode {
            ShapeMode::Gbps => entry.capacity.as_bits_per_sec() / 8.0,
            ShapeMode::Iops => {
                entry.capacity.as_bits_per_sec() / 8.0 / row.size_hint as f64
            }
        };
        new_rate = new_rate.min(cap_units);
    }
    if (new_rate - current).abs() / current < 0.01 {
        return None; // nothing meaningful to change
    }
    Some(Action::Reshape {
        flow,
        rate: new_rate,
        params: TokenBucketParams::for_rate(new_rate, mode),
    })
}

/// PathSelection (Algorithm 1 line 18): if the flow's current path context
/// is capacity-bound below its SLO and the accelerator is reachable via
/// another path with more profiled capacity, move it.
pub fn path_selection(
    profile: &ProfileTable,
    acc_table: &AccTable,
    status: &PerFlowStatusTable,
    flow: FlowId,
) -> Option<Action> {
    let row = status.get(flow)?;
    let (slo_rate, mode) = row.slo.required_rate()?;
    let n = status.flows_on_accel(row.accel).len();
    let cap_of = |path: Path| -> f64 {
        profile
            .capacity(&row.accel_name, path, row.size_hint, n)
            .map(|e| match mode {
                ShapeMode::Gbps => e.capacity.as_bits_per_sec() / 8.0,
                ShapeMode::Iops => {
                    e.capacity.as_bits_per_sec() / 8.0 / row.size_hint as f64
                }
            })
            .unwrap_or(0.0)
    };
    let current_cap = cap_of(row.path);
    if current_cap >= slo_rate {
        return None; // current path can carry the SLO; reshape instead
    }
    let best = acc_table
        .paths(&row.accel_name)
        .iter()
        .filter(|&&p| p != row.path)
        .map(|&p| (p, cap_of(p)))
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())?;
    if best.1 > current_cap * 1.2 && best.1 >= slo_rate {
        Some(Action::SwitchPath {
            flow,
            to: best.0,
        })
    } else {
        None
    }
}

/// Over-commit reconciliation — the reaction path for mis-estimated
/// profiles ([`crate::faults`]'s `ProfileSkew`). Admission can only
/// over-commit an accelerator when it planned against a skewed capacity
/// table; once re-profiling heals the table, the committed SLO sum may
/// exceed what the engine truly sustains. This pass detects that per
/// accelerator and emits renegotiation reshapes clamping every committed
/// flow's shaped rate to its proportional share of the true budget —
/// capacity is honored immediately even though the (unattainable) SLO
/// contracts stay on the books for the operator to renegotiate.
///
/// Quiet in steady state: admission guarantees `committed ≤ budget`
/// whenever the table was honest, so the pass emits nothing.
/// `overcommitted` is the set [`overcommitted_accels`] returned — the
/// caller computes it once per tick and reuses it for boost suppression.
pub fn rebalance_overcommit(
    cfg: &PlannerConfig,
    profile: &ProfileTable,
    status: &PerFlowStatusTable,
    overcommitted: &[usize],
) -> Vec<Action> {
    let mut out = Vec::new();
    for &accel in overcommitted {
        let Some((budget, committed)) = accel_budget(cfg, profile, status, accel) else {
            continue;
        };
        let scale = budget / committed;
        let n = status.flows_on_accel(accel).len();
        for r in status.flows_on_accel(accel) {
            let Some((slo_rate, mode)) = r.slo.required_rate() else { continue };
            if profile.capacity(&r.accel_name, r.path, r.size_hint, n).is_none() {
                continue;
            }
            let rate = slo_rate * scale;
            // Skip flows already at (or below) their clamped share so the
            // pass converges instead of re-emitting every tick.
            if let Some(current) = r.shaped_rate {
                if current <= rate * 1.01 {
                    continue;
                }
            }
            out.push(Action::Reshape {
                flow: r.flow,
                rate,
                params: TokenBucketParams::for_rate(rate, mode),
            });
        }
    }
    out
}

/// Accelerators whose committed SLO sum exceeds the current profiled
/// budget. Non-empty only while admissions made against a mis-estimated
/// table are still on the books; the control plane suppresses compensation
/// boosts on these engines (boosting cannot conjure capacity that does
/// not exist, it only steals from the other over-committed tenants).
pub fn overcommitted_accels(
    cfg: &PlannerConfig,
    profile: &ProfileTable,
    status: &PerFlowStatusTable,
) -> Vec<usize> {
    let mut accels: Vec<usize> = status.iter().map(|r| r.accel).collect();
    accels.sort_unstable();
    accels.dedup();
    accels.retain(|&a| {
        matches!(accel_budget(cfg, profile, status, a),
                 Some((budget, committed)) if committed > budget * 1.001)
    });
    accels
}

/// The admission-CHECK budget (tightest committed context, net of the
/// headroom reserve) and committed SLO sum for one accelerator, both in
/// bytes/sec. `None` when no committed flow has a profiled context there
/// (e.g. storage flows — the SSD is its own authority).
fn accel_budget(
    cfg: &PlannerConfig,
    profile: &ProfileTable,
    status: &PerFlowStatusTable,
    accel: usize,
) -> Option<(f64, f64)> {
    let rows = status.flows_on_accel(accel);
    let n = rows.len();
    let mut capacity_bytes = f64::INFINITY;
    let mut committed = 0.0;
    let mut any = false;
    for r in rows {
        let Some((rate, mode)) = r.slo.required_rate() else { continue };
        let Some(e) = profile.capacity(&r.accel_name, r.path, r.size_hint, n) else {
            continue;
        };
        any = true;
        capacity_bytes = capacity_bytes.min(e.capacity.as_bits_per_sec() / 8.0);
        committed += match mode {
            ShapeMode::Gbps => rate,
            ShapeMode::Iops => rate * r.size_hint as f64,
        };
    }
    if !any || !capacity_bytes.is_finite() {
        return None;
    }
    Some((capacity_bytes * (1.0 - cfg.admission_headroom), committed))
}

/// Per-(engine, tenant) committed-rate sums — the tenant-level aggregates
/// the hierarchical planner commits as shaper-tree nodes, not just flow
/// rates. Units are bytes/sec, and only bandwidth-mode (Gbps) commitments
/// count: IOPS-SLO and storage flows keep flat per-flow buckets even
/// under hierarchy (their cost units would not be commensurable with a
/// bytes-denominated tree pool), so they take no tree budget.
/// Deterministic order: ascending `(accel, vm)`.
pub fn tenant_aggregates(status: &PerFlowStatusTable) -> Vec<(usize, usize, f64)> {
    let mut sums: std::collections::BTreeMap<(usize, usize), f64> =
        std::collections::BTreeMap::new();
    for r in status.iter() {
        if r.accel_name == "storage" {
            continue;
        }
        let Some((rate, ShapeMode::Gbps)) = r.slo.required_rate() else { continue };
        *sums.entry((r.accel, r.vm)).or_insert(0.0) += rate;
    }
    sums.into_iter().map(|((a, v), s)| (a, v, s)).collect()
}

/// One periodic tick of Algorithm 1 (lines 2–6): walk every flow, and for
/// each violating one emit a path switch (preferred when the path itself is
/// the bottleneck) or a reshape. `status` must already hold fresh measured
/// windows (the system records hardware counters before calling).
pub fn run_tick(
    cfg: &PlannerConfig,
    profile: &ProfileTable,
    acc_table: &AccTable,
    status: &PerFlowStatusTable,
) -> Vec<Action> {
    let mut actions = Vec::new();
    for row in status.iter() {
        // Meeting flows that were boosted above their SLO decay back toward
        // it — compensation is temporary, precision is the steady state.
        if row.state == SloState::Meeting {
            if let (Some(shaped), Some((slo_rate, mode))) =
                (row.shaped_rate, row.slo.required_rate())
            {
                let floor = slo_rate * cfg.shaping_headroom;
                if shaped > floor * 1.02 {
                    let rate = (shaped / cfg.boost_step).max(floor);
                    actions.push(Action::Reshape {
                        flow: row.flow,
                        rate,
                        params: TokenBucketParams::for_rate(rate, mode),
                    });
                }
            }
            continue;
        }
        if row.state != SloState::Violating || row.violations < cfg.reshape_after {
            continue;
        }
        if let Some(switch) = path_selection(profile, acc_table, status, row.flow) {
            actions.push(switch);
            continue;
        }
        if let Some(reshape) = reshape_decision(cfg, profile, status, row.flow) {
            actions.push(reshape);
        }
    }
    actions
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::AccelModel;
    use crate::coordinator::status::{FlowStatus, MeasuredWindow};
    use crate::pcie::fabric::FabricConfig;
    use crate::util::units::{Rate, MILLIS};

    fn setup() -> (ProfileTable, AccTable) {
        let profile = ProfileTable::learn(
            &[AccelModel::ipsec_32g(), AccelModel::sha3_512()],
            &FabricConfig::gen3_x8(),
        );
        let mut acc = AccTable::default();
        acc.register(
            "ipsec",
            vec![Path::FunctionCall, Path::InlineNicRx, Path::InlineP2p],
        );
        (profile, acc)
    }

    fn flow(id: FlowId, slo: Slo, size: u64) -> FlowStatus {
        FlowStatus::new(id, id, Path::FunctionCall, 0, "ipsec", slo, size)
    }

    #[test]
    fn admission_accepts_within_capacity() {
        let (profile, _) = setup();
        let status = PerFlowStatusTable::default();
        let cfg = PlannerConfig::default();
        // 10 + 20 Gbps on a 32 Gbps engine at 1500B (~26 Gbps effective):
        // first flow of 10 Gbps fits.
        match admission_control(
            &cfg,
            &profile,
            &status,
            0,
            "ipsec",
            Path::FunctionCall,
            1500,
            &Slo::gbps(10.0),
        ) {
            Admission::Accept { rate, params } => {
                assert!((rate - 1.25e9).abs() < 1.0);
                assert!(params.nominal_rate() > 0.0);
            }
            Admission::Reject { reason } => panic!("rejected: {reason}"),
        }
    }

    #[test]
    fn admission_rejects_over_commitment() {
        let (profile, _) = setup();
        let mut status = PerFlowStatusTable::default();
        let cfg = PlannerConfig::default();
        status.register(flow(0, Slo::gbps(15.0), 1500));
        status.register(flow(1, Slo::gbps(10.0), 1500));
        // Engine sustains ~26 Gbps at 1500 B; 15+10 committed, +8 must fail.
        let verdict = admission_control(
            &cfg,
            &profile,
            &status,
            0,
            "ipsec",
            Path::FunctionCall,
            1500,
            &Slo::gbps(8.0),
        );
        assert!(matches!(verdict, Admission::Reject { .. }), "{verdict:?}");
    }

    #[test]
    fn admission_rejects_slo_violating_context() {
        let (profile, _) = setup();
        let status = PerFlowStatusTable::default();
        let cfg = PlannerConfig::default();
        // 64 B ipsec context is tagged SLO-Violating by the profiler.
        let verdict = admission_control(
            &cfg,
            &profile,
            &status,
            0,
            "ipsec",
            Path::FunctionCall,
            64,
            &Slo::gbps(1.0),
        );
        assert!(matches!(verdict, Admission::Reject { .. }));
    }

    #[test]
    fn best_effort_always_admitted() {
        let (profile, _) = setup();
        let mut status = PerFlowStatusTable::default();
        let cfg = PlannerConfig::default();
        for i in 0..20 {
            status.register(flow(i, Slo::gbps(1.5), 1500));
        }
        let verdict = admission_control(
            &cfg,
            &profile,
            &status,
            0,
            "ipsec",
            Path::FunctionCall,
            1500,
            &Slo::BestEffort,
        );
        assert!(matches!(verdict, Admission::Accept { .. }));
    }

    #[test]
    fn admission_boundary_exactly_at_capacity() {
        // Satellite edge: a request that lands *exactly* on the remaining
        // budget is admitted; one epsilon above is rejected. The check is
        // `committed + requested > budget`, so equality passes.
        let (profile, _) = setup();
        let status = PerFlowStatusTable::default();
        let cfg = PlannerConfig::default();
        let entry = profile
            .capacity("ipsec", Path::FunctionCall, 1500, 1)
            .unwrap();
        let budget_bytes =
            entry.capacity.as_bits_per_sec() / 8.0 * (1.0 - cfg.admission_headroom);
        // Rate(x*8)/8 == x exactly in f64 (power-of-two scaling).
        let at_capacity = Slo::Throughput {
            target: Rate(budget_bytes * 8.0),
            percentile: 99.0,
        };
        let verdict = admission_control(
            &cfg, &profile, &status, 0, "ipsec", Path::FunctionCall, 1500, &at_capacity,
        );
        assert!(matches!(verdict, Admission::Accept { .. }), "{verdict:?}");
        let above = Slo::Throughput {
            target: Rate((budget_bytes + 1.0) * 8.0),
            percentile: 99.0,
        };
        let verdict = admission_control(
            &cfg, &profile, &status, 0, "ipsec", Path::FunctionCall, 1500, &above,
        );
        assert!(matches!(verdict, Admission::Reject { .. }), "{verdict:?}");
    }

    #[test]
    fn renegotiation_excludes_own_commitment() {
        let (profile, _) = setup();
        let cfg = PlannerConfig::default();
        let mut status = PerFlowStatusTable::default();
        status.register(flow(0, Slo::gbps(10.0), 1500));
        status.register(flow(1, Slo::gbps(10.0), 1500));
        // Naively re-admitting 14 on top of 10+10 would fail; excluding the
        // flow's own 10 it fits.
        let v = renegotiation_control(&cfg, &profile, &status, 0, &Slo::gbps(14.0));
        assert!(matches!(v, Admission::Accept { .. }), "{v:?}");
        // 20 exceeds what flow 1 leaves free.
        let v = renegotiation_control(&cfg, &profile, &status, 0, &Slo::gbps(20.0));
        assert!(matches!(v, Admission::Reject { .. }), "{v:?}");
        // Unregistered flows are rejected outright.
        let v = renegotiation_control(&cfg, &profile, &status, 7, &Slo::gbps(1.0));
        assert!(matches!(v, Admission::Reject { .. }));
        // Dropping to best-effort always succeeds.
        let v = renegotiation_control(&cfg, &profile, &status, 0, &Slo::BestEffort);
        assert!(matches!(v, Admission::Accept { .. }));
    }

    #[test]
    fn reshape_boosts_underattaining_flow() {
        let (profile, _) = setup();
        let mut status = PerFlowStatusTable::default();
        let cfg = PlannerConfig::default();
        let mut f = flow(0, Slo::gbps(10.0), 1500);
        f.shaped_rate = Some(1.25e9);
        // Measured only 8 Gbps of a 10 Gbps SLO.
        f.measured = MeasuredWindow {
            span: MILLIS,
            bytes: 1_000_000,
            ops: 667,
            p99_latency: None,
        };
        f.state = SloState::Violating;
        f.violations = 3;
        status.register(f);
        match reshape_decision(&cfg, &profile, &status, 0).unwrap() {
            Action::Reshape { rate, .. } => {
                assert!(rate > 1.25e9, "boosted rate {rate:.3e}");
                assert!(rate <= 1.25e9 * cfg.max_boost * 1.001);
            }
            other => panic!("expected reshape, got {other:?}"),
        }
    }

    #[test]
    fn reshape_noop_when_meeting() {
        let (profile, acc) = setup();
        let mut status = PerFlowStatusTable::default();
        let cfg = PlannerConfig::default();
        let mut f = flow(0, Slo::gbps(10.0), 1500);
        f.shaped_rate = Some(1.25e9);
        f.measured = MeasuredWindow {
            span: MILLIS,
            bytes: 1_300_000, // 10.4 Gbps
            ops: 867,
            p99_latency: None,
        };
        f.state = f.check();
        status.register(f);
        assert!(run_tick(&cfg, &profile, &acc, &status).is_empty());
    }

    #[test]
    fn path_selection_moves_capacity_bound_flow() {
        let (mut profile, acc) = setup();
        // Force FunctionCall context capacity below SLO, keep InlineNicRx
        // plentiful (as if Down direction were congested).
        profile.observe(
            crate::coordinator::profile::ProfileKey {
                accel: "ipsec".into(),
                path: Path::FunctionCall,
                size: 1500,
                n_flows: 1,
            },
            Rate::gbps(5.0),
            true,
        );
        let mut status = PerFlowStatusTable::default();
        let mut f = flow(0, Slo::gbps(10.0), 1500);
        f.state = SloState::Violating;
        f.violations = 5;
        status.register(f);
        let actions = run_tick(&PlannerConfig::default(), &profile, &acc, &status);
        assert!(
            actions
                .iter()
                .any(|a| matches!(a, Action::SwitchPath { to, .. } if *to != Path::FunctionCall)),
            "actions={actions:?}"
        );
    }

    #[test]
    fn boosted_meeting_flow_decays_toward_slo() {
        let (profile, acc) = setup();
        let cfg = PlannerConfig::default();
        let mut status = PerFlowStatusTable::default();
        let mut f = flow(0, Slo::gbps(10.0), 1500);
        f.shaped_rate = Some(1.25e9 * 1.3); // boosted to 13 G
        f.measured = MeasuredWindow {
            span: MILLIS,
            bytes: 1_400_000, // 11.2 Gbps: meeting
            ops: 933,
            p99_latency: None,
        };
        f.state = f.check();
        status.register(f);
        let actions = run_tick(&cfg, &profile, &acc, &status);
        match &actions[..] {
            [Action::Reshape { rate, .. }] => {
                assert!(*rate < 1.25e9 * 1.3, "decayed: {rate:.3e}");
                assert!(*rate >= 1.25e9, "never below the SLO rate");
            }
            other => panic!("expected one decay reshape, got {other:?}"),
        }
    }

    #[test]
    fn rebalance_clamps_overcommit_to_true_budget() {
        let (profile, _) = setup();
        let cfg = PlannerConfig::default();
        let mut status = PerFlowStatusTable::default();
        // 3 × 12 Gbps committed on an engine whose true budget is ~24.6
        // Gbps at 1500 B — only possible if admission planned against a
        // skewed table (the ProfileSkew fault).
        for i in 0..3 {
            let mut f = flow(i, Slo::gbps(12.0), 1500);
            f.shaped_rate = Some(12e9 / 8.0 * 1.01);
            status.register(f);
        }
        let over = overcommitted_accels(&cfg, &profile, &status);
        assert_eq!(over, vec![0]);
        let actions = rebalance_overcommit(&cfg, &profile, &status, &over);
        assert_eq!(actions.len(), 3, "{actions:?}");
        let entry = profile.capacity("ipsec", Path::FunctionCall, 1500, 3).unwrap();
        let budget =
            entry.capacity.as_bits_per_sec() / 8.0 * (1.0 - cfg.admission_headroom);
        let total: f64 = actions
            .iter()
            .map(|a| match a {
                Action::Reshape { rate, .. } => *rate,
                _ => 0.0,
            })
            .sum();
        assert!(total <= budget * 1.001, "clamped sum {total:.3e} > budget {budget:.3e}");
        assert!(total >= budget * 0.98, "clamp wastes capacity: {total:.3e}");
        // Equal SLOs get equal shares.
        if let [Action::Reshape { rate: a, .. }, Action::Reshape { rate: b, .. }, ..] =
            &actions[..]
        {
            assert!((a - b).abs() < 1.0);
        }
    }

    #[test]
    fn rebalance_quiet_when_honestly_committed() {
        let (profile, _) = setup();
        let cfg = PlannerConfig::default();
        let mut status = PerFlowStatusTable::default();
        for i in 0..2 {
            let mut f = flow(i, Slo::gbps(10.0), 1500);
            f.shaped_rate = Some(10e9 / 8.0 * 1.01);
            status.register(f);
        }
        let over = overcommitted_accels(&cfg, &profile, &status);
        assert!(over.is_empty());
        assert!(rebalance_overcommit(&cfg, &profile, &status, &over).is_empty());
        // Already-clamped flows are not re-emitted (convergence).
        let mut status = PerFlowStatusTable::default();
        for i in 0..3 {
            let mut f = flow(i, Slo::gbps(12.0), 1500);
            f.shaped_rate = Some(1e9 / 8.0); // far below any clamped share
            status.register(f);
        }
        let over = overcommitted_accels(&cfg, &profile, &status);
        assert!(!over.is_empty());
        assert!(rebalance_overcommit(&cfg, &profile, &status, &over).is_empty());
    }

    #[test]
    fn tick_respects_hysteresis() {
        let (profile, acc) = setup();
        let cfg = PlannerConfig::default();
        let mut status = PerFlowStatusTable::default();
        let mut f = flow(0, Slo::gbps(30.0), 1500);
        f.shaped_rate = Some(30e9 / 8.0);
        f.measured = MeasuredWindow {
            span: MILLIS,
            bytes: 100_000,
            ops: 67,
            p99_latency: None,
        };
        f.state = SloState::Violating;
        f.violations = 1; // below reshape_after=2
        status.register(f);
        assert!(run_tick(&cfg, &profile, &acc, &status).is_empty());
    }
}
