//! Deterministic pseudo-random number generation for the simulator.
//!
//! Every stochastic component (traffic generators, jitter models, service-time
//! distributions) owns its own [`Rng`] seeded from the experiment seed plus a
//! stream id, so adding a component never perturbs the random sequence seen by
//! another — experiments stay reproducible run-to-run and diff cleanly when
//! the topology changes.
//!
//! The generator is xoshiro256++ (public domain reference by Blackman/Vigna)
//! seeded through SplitMix64, which is more than adequate statistically for a
//! discrete-event simulation and costs a handful of ALU ops per draw.

/// SplitMix64 step; used for seeding and for hashing stream ids into seeds.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ deterministic PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for component `stream` under `seed`.
    ///
    /// Streams with different ids are decorrelated even for adjacent seeds.
    pub fn for_stream(seed: u64, stream: u64) -> Self {
        let mut sm = seed ^ stream.wrapping_mul(0xA24B_AED4_963E_E407);
        // Burn a few rounds so similar (seed, stream) pairs diverge.
        for _ in 0..4 {
            splitmix64(&mut sm);
        }
        Rng::new(sm)
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform u64 in [0, bound) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        let span = hi - lo;
        if span == u64::MAX {
            return self.next_u64(); // full range
        }
        lo + self.below(span + 1)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponentially distributed value with the given mean (inverse-CDF).
    #[inline]
    pub fn exponential(&mut self, mean: f64) -> f64 {
        debug_assert!(mean > 0.0);
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller (one value per call; simple, adequate).
    pub fn normal(&mut self, mean: f64, std_dev: f64) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std_dev * z
    }

    /// Pareto-distributed value (heavy tail) with scale `xm` and shape `alpha`.
    ///
    /// Used by the CPU-interference jitter model of the software shapers and
    /// the population workload's message-size distribution: scheduler hiccups
    /// and user demand are both well-known to be heavy-tailed.
    ///
    /// Requires finite `xm > 0` and `alpha > 0`; anything else used to
    /// produce NaN/inf that poisoned downstream averages silently. Draws are
    /// always finite and ≥ `xm`: for extreme-but-valid shapes (tiny `alpha`)
    /// the inverse CDF can overflow `f64`, in which case the draw saturates
    /// to `f64::MAX` rather than leaking `inf`.
    pub fn pareto(&mut self, xm: f64, alpha: f64) -> f64 {
        assert!(
            xm > 0.0 && alpha > 0.0 && xm.is_finite() && alpha.is_finite(),
            "pareto requires finite xm > 0 and alpha > 0 (got xm={xm}, alpha={alpha})"
        );
        let u = 1.0 - self.f64(); // (0, 1]
        let x = xm / u.powf(1.0 / alpha);
        if x.is_finite() {
            x.max(xm)
        } else {
            f64::MAX
        }
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_runs() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_are_decorrelated() {
        let mut a = Rng::for_stream(1, 0);
        let mut b = Rng::for_stream(1, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(9);
        let mut counts = [0u32; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            // Each bucket should be near 10_000; 5% tolerance is generous.
            assert!((9_000..11_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut r = Rng::new(3);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            match r.range_u64(5, 8) {
                5 => lo_seen = true,
                8 => hi_seen = true,
                6 | 7 => {}
                other => panic!("out of range: {other}"),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn exponential_mean_close() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let sum: f64 = (0..n).map(|_| r.exponential(4.0)).sum();
        let mean = sum / n as f64;
        assert!((mean - 4.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn pareto_is_bounded_below() {
        let mut r = Rng::new(13);
        for _ in 0..10_000 {
            assert!(r.pareto(2.0, 1.5) >= 2.0);
        }
    }

    #[test]
    fn pareto_stays_finite_under_extreme_valid_shapes() {
        // Tiny alpha drives 1/u^(1/alpha) toward overflow for small u; the
        // draw must saturate, never return inf/NaN. Tiny xm must still act
        // as a hard lower bound, and huge xm must not round below itself.
        let mut r = Rng::new(29);
        for &(xm, alpha) in &[(1e-12, 0.01), (2.0, 0.05), (1e12, 0.5), (512.0, 8.0)] {
            for _ in 0..20_000 {
                let x = r.pareto(xm, alpha);
                assert!(x.is_finite(), "xm={xm} alpha={alpha} gave {x}");
                assert!(x >= xm, "xm={xm} alpha={alpha} gave {x} below scale");
            }
        }
    }

    #[test]
    #[should_panic(expected = "pareto requires")]
    fn pareto_rejects_nonpositive_alpha() {
        Rng::new(1).pareto(2.0, 0.0);
    }

    #[test]
    #[should_panic(expected = "pareto requires")]
    fn pareto_rejects_nonpositive_xm() {
        Rng::new(1).pareto(-1.0, 1.5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
