//! Deterministic slab allocator for in-flight operation state.
//!
//! The engine used to key per-operation context by a monotonically growing
//! `u64` in a `HashMap` — one hash + probe per packet stage, plus rehash
//! churn as the map grows. A slab keeps contexts in a flat `Vec` and hands
//! out *reused* indices from a LIFO free list: lookups are a bounds-checked
//! array index, insertion never rehashes, and the id space stays small so
//! downstream id packing (e.g. the fabric's `op << 2 | phase` message ids)
//! never overflows.
//!
//! Determinism: the free list is LIFO and all operations are O(1) with no
//! hashing, so two identical runs hand out identical ids in identical
//! order — slab ids are safe to use in any code path that must replay
//! byte-identically.

/// A slab of `T` keyed by reusable `u64` ids.
#[derive(Debug, Clone)]
pub struct Slab<T> {
    entries: Vec<Option<T>>,
    free: Vec<u64>,
    live: usize,
}

impl<T> Default for Slab<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Slab<T> {
    pub fn new() -> Self {
        Slab {
            entries: Vec::new(),
            free: Vec::new(),
            live: 0,
        }
    }

    pub fn with_capacity(cap: usize) -> Self {
        Slab {
            entries: Vec::with_capacity(cap),
            free: Vec::new(),
            live: 0,
        }
    }

    /// Store `value`, returning its id. Ids are reused LIFO after removal.
    pub fn insert(&mut self, value: T) -> u64 {
        self.live += 1;
        match self.free.pop() {
            Some(id) => {
                debug_assert!(self.entries[id as usize].is_none());
                self.entries[id as usize] = Some(value);
                id
            }
            None => {
                self.entries.push(Some(value));
                (self.entries.len() - 1) as u64
            }
        }
    }

    /// Take the value out, freeing the id for reuse. Returns `None` for
    /// ids that are not live (already removed or never issued).
    pub fn remove(&mut self, id: u64) -> Option<T> {
        let v = self.entries.get_mut(id as usize)?.take();
        if v.is_some() {
            self.free.push(id);
            self.live -= 1;
        }
        v
    }

    pub fn get(&self, id: u64) -> Option<&T> {
        self.entries.get(id as usize)?.as_ref()
    }

    pub fn get_mut(&mut self, id: u64) -> Option<&mut T> {
        self.entries.get_mut(id as usize)?.as_mut()
    }

    /// Live entries (not slots).
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Allocated slots (high-water mark of concurrent liveness).
    pub fn capacity_used(&self) -> usize {
        self.entries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_remove_roundtrip() {
        let mut s = Slab::new();
        let a = s.insert("a");
        let b = s.insert("b");
        assert_ne!(a, b);
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(a), Some(&"a"));
        assert_eq!(s.remove(a), Some("a"));
        assert_eq!(s.remove(a), None, "double remove is None");
        assert_eq!(s.len(), 1);
        assert_eq!(s.get(b), Some(&"b"));
    }

    #[test]
    fn ids_reuse_lifo_and_deterministically() {
        let run = || {
            let mut s = Slab::new();
            let mut ids = Vec::new();
            for i in 0..8u32 {
                ids.push(s.insert(i));
            }
            // Remove a few, insert again: freed ids come back LIFO.
            s.remove(ids[2]);
            s.remove(ids[5]);
            let x = s.insert(100);
            let y = s.insert(101);
            (ids, x, y)
        };
        let (ids, x, y) = run();
        assert_eq!(x, ids[5], "last freed, first reused");
        assert_eq!(y, ids[2]);
        assert_eq!(run(), (ids, x, y), "identical runs hand out identical ids");
    }

    #[test]
    fn slot_count_tracks_peak_concurrency_not_total_traffic() {
        let mut s = Slab::new();
        for i in 0..1000u64 {
            let id = s.insert(i);
            s.remove(id);
        }
        assert_eq!(s.capacity_used(), 1, "serial reuse needs one slot");
        assert!(s.is_empty());
    }
}
