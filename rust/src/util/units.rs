//! Time, rate, and size units used throughout the simulator and coordinator.
//!
//! Virtual time is an integer count of **picoseconds** (`Time`), which gives
//! exact cycle arithmetic at the paper's 250 MHz FPGA clock (1 cycle =
//! 4000 ps) and sub-nanosecond resolution for PCIe serialization times
//! without floating-point drift in the event queue.

/// Virtual time in picoseconds.
pub type Time = u64;

/// One nanosecond in picoseconds.
pub const NANOS: Time = 1_000;
/// One microsecond in picoseconds.
pub const MICROS: Time = 1_000_000;
/// One millisecond in picoseconds.
pub const MILLIS: Time = 1_000_000_000;
/// One second in picoseconds.
pub const SECONDS: Time = 1_000_000_000_000;

/// The Arcus FPGA prototype clock: 250 MHz, i.e. 4 ns per cycle (§5.1).
pub const FPGA_CLOCK_HZ: u64 = 250_000_000;
/// Picoseconds per FPGA cycle.
pub const PS_PER_CYCLE: Time = SECONDS / FPGA_CLOCK_HZ; // 4000

/// Convert FPGA cycles to picoseconds.
#[inline]
pub const fn cycles(n: u64) -> Time {
    n * PS_PER_CYCLE
}

/// Convert picoseconds to (whole) FPGA cycles.
#[inline]
pub const fn to_cycles(t: Time) -> u64 {
    t / PS_PER_CYCLE
}

/// Format a time for human-readable reports.
pub fn fmt_time(t: Time) -> String {
    if t >= SECONDS {
        format!("{:.3}s", t as f64 / SECONDS as f64)
    } else if t >= MILLIS {
        format!("{:.3}ms", t as f64 / MILLIS as f64)
    } else if t >= MICROS {
        format!("{:.3}us", t as f64 / MICROS as f64)
    } else if t >= NANOS {
        format!("{:.3}ns", t as f64 / NANOS as f64)
    } else {
        format!("{t}ps")
    }
}

/// A data rate. Stored as bits per second (f64) with conversion helpers.
///
/// SLOs in the paper are expressed either in Gbps (bandwidth SLOs) or IOPS
/// (operation-rate SLOs); [`Rate`] covers the former, IOPS are plain f64.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd)]
pub struct Rate(pub f64);

impl Rate {
    pub const ZERO: Rate = Rate(0.0);

    #[inline]
    pub fn gbps(g: f64) -> Rate {
        Rate(g * 1e9)
    }
    #[inline]
    pub fn mbps(m: f64) -> Rate {
        Rate(m * 1e6)
    }
    #[inline]
    pub fn bits_per_sec(b: f64) -> Rate {
        Rate(b)
    }

    #[inline]
    pub fn as_gbps(self) -> f64 {
        self.0 / 1e9
    }
    #[inline]
    pub fn as_bits_per_sec(self) -> f64 {
        self.0
    }
    /// Bytes transferred per picosecond at this rate.
    #[inline]
    pub fn bytes_per_ps(self) -> f64 {
        self.0 / 8.0 / SECONDS as f64
    }

    /// Time (ps) to serialize `bytes` at this rate. Saturates to `Time::MAX`
    /// for a zero rate so a stalled link never produces a bogus 0-delay event.
    #[inline]
    pub fn serialize_time(self, bytes: u64) -> Time {
        if self.0 <= 0.0 {
            return Time::MAX;
        }
        let ps = (bytes as f64 * 8.0) * SECONDS as f64 / self.0;
        ps.ceil() as Time
    }
}

impl std::ops::Add for Rate {
    type Output = Rate;
    fn add(self, rhs: Rate) -> Rate {
        Rate(self.0 + rhs.0)
    }
}
impl std::ops::Sub for Rate {
    type Output = Rate;
    fn sub(self, rhs: Rate) -> Rate {
        Rate(self.0 - rhs.0)
    }
}
impl std::ops::Mul<f64> for Rate {
    type Output = Rate;
    fn mul(self, k: f64) -> Rate {
        Rate(self.0 * k)
    }
}
impl std::fmt::Display for Rate {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.0 >= 1e9 {
            write!(f, "{:.2}Gbps", self.0 / 1e9)
        } else if self.0 >= 1e6 {
            write!(f, "{:.2}Mbps", self.0 / 1e6)
        } else {
            write!(f, "{:.0}bps", self.0)
        }
    }
}

/// Message/payload sizes in bytes; helpers for the sizes the paper sweeps.
pub const KB: u64 = 1024;
pub const MB: u64 = 1024 * 1024;
/// MTU-sized message used throughout the paper's experiments.
pub const MTU: u64 = 1500;

/// Measure achieved throughput: bytes over a virtual-time window.
#[inline]
pub fn throughput(bytes: u64, window: Time) -> Rate {
    if window == 0 {
        return Rate::ZERO;
    }
    Rate(bytes as f64 * 8.0 * SECONDS as f64 / window as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycle_roundtrip() {
        assert_eq!(PS_PER_CYCLE, 4000);
        assert_eq!(cycles(64), 256_000); // Table 2: 64 cycles = 256 ns
        assert_eq!(to_cycles(cycles(1000)), 1000);
    }

    #[test]
    fn serialize_time_matches_rate() {
        // 1500B at 50 Gbps = 1500*8/50e9 s = 240 ns.
        let t = Rate::gbps(50.0).serialize_time(1500);
        assert_eq!(t, 240 * NANOS);
    }

    #[test]
    fn serialize_time_zero_rate_saturates() {
        assert_eq!(Rate::ZERO.serialize_time(100), Time::MAX);
    }

    #[test]
    fn throughput_inverse_of_serialize() {
        let r = Rate::gbps(32.0);
        let t = r.serialize_time(1_000_000);
        let back = throughput(1_000_000, t);
        assert!((back.as_gbps() - 32.0).abs() < 0.01);
    }

    #[test]
    fn fmt_time_units() {
        assert_eq!(fmt_time(500), "500ps");
        assert_eq!(fmt_time(2 * MICROS), "2.000us");
        assert_eq!(fmt_time(3 * SECONDS), "3.000s");
    }

    #[test]
    fn rate_display() {
        assert_eq!(Rate::gbps(32.0).to_string(), "32.00Gbps");
        assert_eq!(Rate::mbps(5.0).to_string(), "5.00Mbps");
    }
}
