//! Shared utilities: deterministic RNG, units, and small helpers.

pub mod rng;
pub mod slab;
pub mod units;
pub mod varint;

pub use rng::Rng;
pub use slab::Slab;
pub use units::{Rate, Time};

/// Round `x` up to the next multiple of `m` (m > 0).
#[inline]
pub fn round_up(x: u64, m: u64) -> u64 {
    debug_assert!(m > 0);
    x.div_ceil(m) * m
}

/// Integer ceiling division.
#[inline]
pub fn div_ceil(a: u64, b: u64) -> u64 {
    a.div_ceil(b)
}

/// Clamp a float into [lo, hi].
#[inline]
pub fn clampf(x: f64, lo: f64, hi: f64) -> f64 {
    x.max(lo).min(hi)
}

/// Simple exponentially-weighted moving average used by monitors.
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ewma { alpha, value: None }
    }
    pub fn observe(&mut self, x: f64) {
        self.value = Some(match self.value {
            None => x,
            Some(v) => self.alpha * x + (1.0 - self.alpha) * v,
        });
    }
    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        assert!(e.get().is_none());
        for _ in 0..64 {
            e.observe(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-9);
    }
}
