//! LEB128 varint encoding shared by the binary codecs.
//!
//! Both on-disk formats (`obs::dump`'s series dumps and `workload::trace`'s
//! arrival traces) encode integers this way; extracting the pair here keeps
//! the overlong-encoding rejection and truncation discipline tested once and
//! used everywhere instead of drifting per-codec.

/// Append `v` as an LEB128 varint.
pub fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

/// Decode an LEB128 varint at `*pos`, advancing it past the encoding.
///
/// Truncated and overlong encodings fail loudly; a canonical encoder never
/// produces more than ten bytes, and the tenth may only carry bit 63.
pub fn get_varint(buf: &[u8], pos: &mut usize) -> Result<u64, String> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let &b = buf.get(*pos).ok_or("truncated varint")?;
        *pos += 1;
        // A u64 holds 64 payload bits: nine full 7-bit groups plus one final
        // bit. The tenth byte may therefore only carry bit 63 (value 0 or 1,
        // no continuation); anything else would shift payload bits off the
        // top and decode to a silently wrong value.
        if shift >= 64 || (shift == 63 && b & !0x01 != 0) {
            return Err("varint overflow".into());
        }
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn varint_round_trip() {
        let mut buf = Vec::new();
        let cases = [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX];
        for &v in &cases {
            put_varint(&mut buf, v);
        }
        let mut pos = 0;
        for &v in &cases {
            assert_eq!(get_varint(&buf, &mut pos).unwrap(), v);
        }
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn varint_rejects_overlong_encodings() {
        // Nine 0xff continuation bytes put the decoder at shift 63 with
        // bit 63 still unset. A final byte with any payload above bit 0
        // would shift bits past the top of the u64 — the pre-fix decoder
        // masked them off and returned a wrong value.
        let mut hostile = vec![0xffu8; 9];
        hostile.push(0x7f);
        let mut pos = 0;
        assert_eq!(
            get_varint(&hostile, &mut pos),
            Err("varint overflow".into()),
            "tenth byte with payload bits beyond 64 must error, not truncate"
        );

        // A continuation bit on the tenth byte promises an eleventh group
        // that cannot fit either.
        let all_cont = vec![0xffu8; 11];
        let mut pos = 0;
        assert!(get_varint(&all_cont, &mut pos).is_err());

        // The boundary cases stay valid: u64::MAX is nine 0xff bytes plus
        // a final 0x01, and 1 << 63 is nine 0x80 bytes plus 0x01.
        let mut max = vec![0xffu8; 9];
        max.push(0x01);
        let mut pos = 0;
        assert_eq!(get_varint(&max, &mut pos), Ok(u64::MAX));
        let mut top_bit = vec![0x80u8; 9];
        top_bit.push(0x01);
        let mut pos = 0;
        assert_eq!(get_varint(&top_bit, &mut pos), Ok(1u64 << 63));
    }

    #[test]
    fn every_prefix_of_a_stream_errors_loudly() {
        let mut buf = Vec::new();
        for &v in &[0u64, 300, u64::MAX, 1 << 62, 127, 128] {
            put_varint(&mut buf, v);
        }
        // Cutting the stream mid-varint must always surface "truncated",
        // never a silently short value. Prefixes that end exactly on a
        // varint boundary decode cleanly, so walk each prefix to its end
        // and require the error only when the cut is mid-encoding.
        for cut in 0..buf.len() {
            let mut pos = 0;
            loop {
                match get_varint(&buf[..cut], &mut pos) {
                    Ok(_) => {
                        if pos == cut {
                            break; // clean boundary — remaining stream empty
                        }
                    }
                    Err(e) => {
                        assert_eq!(e, "truncated varint", "cut={cut}");
                        break;
                    }
                }
            }
        }
    }
}
