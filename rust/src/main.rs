//! `arcus` — CLI launcher for the Arcus reproduction.
//!
//! Subcommands:
//!   quickstart                     two-flow demo: Arcus vs unshaped baseline
//!   simulate <config.toml> [...]   run experiment configs on the simulator
//!   sweep [axis flags]             expand a scenario grid and run it in parallel
//!   trace record|replay [...]      record / replay a population arrival trace
//!   churn                          tenant-churn demo: mid-run admission/rejection
//!   chaos                          fault-injection demo: degradation, adversaries, recovery
//!   fleet [flags]                  multi-host demo: versioned directive distribution + staleness
//!   bench [flags]                  DES perf presets → BENCH_<name>.json (+ CI floor gate)
//!   top <series.bin> [--limit N]   worst flows/tenants from a --series-out dump
//!   profile [accel ...]            print the offline Capacity(t, X, N) table
//!   serve [--artifacts DIR]        start the PJRT serving runtime + demo load
//!   modes                          list management modes and accelerators
//!
//! (Hand-rolled argument handling: `clap` is not in the offline registry.)

use std::path::PathBuf;

// The allocation-count regression gate (`bench --floor` with the
// `bench-alloc` feature) needs the counting allocator installed for the
// whole process; it forwards to the system allocator with one relaxed
// atomic increment per alloc/realloc.
#[cfg(feature = "bench-alloc")]
#[global_allocator]
static GLOBAL: arcus::perf::alloc::CountingAlloc = arcus::perf::alloc::CountingAlloc;

use arcus::accel::AccelModel;
use arcus::config::{spec_from_document, Document};
use arcus::coordinator::ProfileTable;
use arcus::flow::pattern::Burstiness;
use arcus::flow::{FlowSpec, Path, Slo, TrafficPattern};
use arcus::pcie::fabric::FabricConfig;
use arcus::faults::{FaultKind, FaultSpec};
use arcus::sweep::{
    aggregate, parse_burst, Churn, ControlKind, FaultProfile, GridBase, Scale, SizeMix, SweepGrid,
    SweepRunner,
};
use arcus::system::{run, ExperimentSpec, LifecycleEvent, Mode};
use arcus::util::units::{Rate, MILLIS};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("quickstart") => quickstart(),
        Some("simulate") => simulate(&args[1..]),
        Some("sweep") => sweep(&args[1..]),
        Some("trace") => trace_cmd(&args[1..]),
        Some("churn") => churn(),
        Some("chaos") => chaos(),
        Some("fleet") => fleet(&args[1..]),
        Some("bench") => bench(&args[1..]),
        Some("top") => top(&args[1..]),
        Some("profile") => profile(&args[1..]),
        Some("serve") => serve(&args[1..]),
        Some("modes") => modes(),
        Some("--help") | Some("-h") | None => {
            usage();
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand `{other}`\n");
            usage();
            2
        }
    };
    std::process::exit(code);
}

fn usage() {
    println!(
        "arcus — SLO management for accelerators with traffic shaping\n\n\
         USAGE:\n  arcus quickstart\n  arcus simulate <config.toml> [more.toml ...] [--faults] [--expect-flows N]\n  \
             [--prom-out FILE] [--series-out FILE]\n  \
         arcus sweep [--modes a,b] [--tenants 1,2,4] [--mixes mtu,bulk] [--bursts paced,poisson]\n  \
             [--tightness 0.5,0.8] [--churn static,arrivals] [--faults healthy,accel_dip,rogue]\n  \
             [--flows flat,16,256,4k,10k] [--control static,adaptive] [--hosts 1,2,4]\n  \
             [--population 0,10000,100000] [--accels ipsec] [--seeds 1,2]\n  \
             [--duration-ms N] [--load F] [--threads N] [--scenarios] [--expect-flows N]\n  \
             [--prom-out FILE]\n  \
         arcus trace record <config.toml> --out <trace.bin>\n  \
         arcus trace replay <config.toml> <trace.bin> [--verify]\n  \
         arcus churn\n  arcus chaos\n  \
         arcus fleet [--hosts N] [--delay-us N]\n  \
         arcus bench [--quick] [--preset small|medium|large|xlarge|fleet|population|all] [--queue heap|calendar|wheel|both|all]\n  \
             [--out FILE] [--floor perf_floor.toml] [--no-files] [--verify]\n  \
         arcus top <series.bin> [--limit N]\n  \
         arcus profile [accel ...]\n  arcus serve [--artifacts DIR]\n  arcus modes\n\n\
         Experiment configs: see rust/configs/*.toml (churn.toml shows the\n\
         flow-lifecycle schedule, hierarchy.toml the shaper tree). Paper\n\
         benches: `cargo bench`.\n\
         `sweep --flows` scales the roster past one flow per tenant; non-flat\n\
         cells shape through the hierarchical tree (per-tenant aggregates).\n\
         `sweep --control` compares the static Arcus planner against the\n\
         closed-loop adaptive wrapper (AIMD fast tier + aggregate re-planner).\n\
         `sweep --hosts` shards cells across fleet hosts under versioned,\n\
         ACKed delta directive distribution; `arcus fleet` demos how\n\
         propagation delay + drop windows (stale config) degrade fault-era\n\
         SLO attainment.\n\
         `sweep --population` drives cells from the heavy-tailed user\n\
         population generator (0 = the legacy per-flow patterns); population\n\
         cells add per-user fairness metrics (Jain's index, worst-user p99)\n\
         to every report. `arcus trace record` enumerates a [population]\n\
         config's arrivals into a compact varint binary trace; `replay` runs\n\
         it back through the engine (--verify checks the replayed canonical\n\
         report is byte-identical to the generator run).\n\
         `bench` writes BENCH_<preset>.json per preset, gates on the committed\n\
         events/sec floor when --floor is given (CI perf-smoke; per-preset\n\
         keys like min_events_per_sec_xlarge override the shared floor), and\n\
         with --verify asserts byte-identical canonical reports across the\n\
         event-queue disciplines (the 10k-flow determinism gate). A committed\n\
         min_adaptive_ev_ratio additionally runs the static-vs-adaptive\n\
         profile pair and bounds the closed loop's throughput overhead.\n\
         `--prom-out` writes Prometheus text exposition of the run(s);\n\
         `simulate --series-out` dumps the sampled observability series\n\
         (crate::obs) for `arcus top`, which ranks the worst flows and\n\
         tenants by SLO attainment and window p99."
    );
}

fn modes() -> i32 {
    println!("management modes (§5.1):");
    for m in Mode::ALL {
        println!("  {}", m.name());
    }
    println!("\naccelerator models (effective Gbps at 64B / 1500B / 64KB):");
    for name in ["ipsec", "aes128", "sha1hmac", "sha3_512", "compress", "decompress", "checksum"] {
        let m = AccelModel::by_name(name).unwrap();
        println!(
            "  {:<10} {:>7.2} / {:>7.2} / {:>7.2}",
            name,
            m.effective_rate(64).as_gbps(),
            m.effective_rate(1500).as_gbps(),
            m.effective_rate(65536).as_gbps()
        );
    }
    0
}

fn quickstart() -> i32 {
    println!("Two tenants share a 32 Gbps IPSec engine. SLOs: 10 and 12 Gbps.");
    println!("Both offer ~16 Gbps (oversubscribed). Arcus shapes; the baseline doesn't.\n");
    let line = Rate::gbps(32.0);
    let flows = vec![
        FlowSpec::new(0, 0, Path::FunctionCall, TrafficPattern::fixed(1500, 0.5, line), Slo::gbps(10.0), 0),
        FlowSpec::new(1, 1, Path::FunctionCall, TrafficPattern::fixed(1500, 0.5, line), Slo::gbps(12.0), 0),
    ];
    for mode in [Mode::Arcus, Mode::HostNoTs] {
        let spec = ExperimentSpec::new(mode, vec![AccelModel::ipsec_32g()], flows.clone())
            .with_duration(10 * MILLIS)
            .with_warmup(MILLIS);
        let report = run(&spec);
        println!("=== {} ===", mode.name());
        print!("{}", report.render());
        println!();
    }
    println!("Arcus lands each tenant exactly on its SLO with ~0% variance;");
    println!("the unshaped baseline splits the engine evenly, ignoring what anyone paid for.");
    0
}

fn simulate(args: &[String]) -> i32 {
    // `--expect-flows N`: fail loudly when the runs produce fewer per-flow
    // report rows than expected (CI smoke steps use it so an empty or
    // truncated report can never pass as green). `--faults`: print the
    // per-era fault table for configs carrying a [[faults]] plan.
    let mut expect_flows: Option<usize> = None;
    let mut show_faults = false;
    let mut prom_out: Option<PathBuf> = None;
    let mut series_out: Option<PathBuf> = None;
    let mut paths: Vec<&String> = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--expect-flows" {
            match args.get(i + 1).and_then(|v| v.parse::<usize>().ok()) {
                Some(n) => expect_flows = Some(n),
                None => {
                    eprintln!("--expect-flows needs a non-negative integer");
                    return 2;
                }
            }
            i += 2;
        } else if args[i] == "--prom-out" || args[i] == "--series-out" {
            let Some(v) = args.get(i + 1) else {
                eprintln!("{} needs a file path", args[i]);
                return 2;
            };
            if args[i] == "--prom-out" {
                prom_out = Some(PathBuf::from(v));
            } else {
                series_out = Some(PathBuf::from(v));
            }
            i += 2;
        } else if args[i] == "--faults" {
            show_faults = true;
            i += 1;
        } else {
            paths.push(&args[i]);
            i += 1;
        }
    }
    if paths.is_empty() {
        eprintln!(
            "usage: arcus simulate <config.toml> [more.toml ...] [--faults] [--expect-flows N] \
             [--prom-out FILE] [--series-out FILE]"
        );
        return 2;
    }
    let mut faulted_runs = 0usize;
    let mut total_flows = 0usize;
    // Reports are kept only when an exporter needs them after the loop.
    let keep_reports = prom_out.is_some() || series_out.is_some();
    let mut reports: Vec<(String, arcus::system::SystemReport)> = Vec::new();
    for p in paths {
        let path = PathBuf::from(p);
        let doc = match Document::from_file(&path) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("{}: {e:#}", path.display());
                return 1;
            }
        };
        let spec = match spec_from_document(&doc) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("{}: {e:#}", path.display());
                return 1;
            }
        };
        let fleet_cfg = match arcus::config::fleet_from_document(&doc) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("{}: {e:#}", path.display());
                return 1;
            }
        };
        println!("=== {} ===", path.display());
        let report = match &fleet_cfg {
            Some(cfg) => arcus::fleet::run(&spec, cfg),
            None => run(&spec),
        };
        total_flows += report.per_flow.len();
        print!("{}", report.render());
        for f in &report.per_flow {
            if f.rejected {
                println!("flow {}: REJECTED by admission control", f.flow);
            } else if let Some(att) = f.slo_attainment() {
                println!("flow {}: SLO attainment {:.1}%", f.flow, att * 100.0);
            }
        }
        println!(
            "pcie util up/down: {:.0}%/{:.0}%  accel util: {:?}",
            report.pcie_up_util * 100.0,
            report.pcie_down_util * 100.0,
            report.accel_util.iter().map(|u| (u * 100.0).round()).collect::<Vec<_>>()
        );
        if show_faults {
            let table = report.render_fault_eras();
            if table.is_empty() {
                println!("(no [[faults]] plan in this config — nothing to report)");
            } else {
                faulted_runs += 1;
                print!("{table}");
            }
        }
        println!();
        if keep_reports {
            let label = path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| path.display().to_string());
            reports.push((label, report));
        }
    }
    if let Some(n) = expect_flows {
        if total_flows < n {
            eprintln!("expected at least {n} flow reports, got {total_flows}");
            return 1;
        }
    }
    if show_faults && faulted_runs == 0 {
        eprintln!("--faults was given but no config carried a [[faults]] plan");
        return 1;
    }
    if let Some(path) = prom_out {
        let labeled: Vec<(String, &arcus::system::SystemReport)> =
            reports.iter().map(|(l, r)| (l.clone(), r)).collect();
        if let Err(e) = std::fs::write(&path, arcus::obs::prom::render(&labeled)) {
            eprintln!("writing {}: {e}", path.display());
            return 1;
        }
        eprintln!("wrote {}", path.display());
    }
    if let Some(path) = series_out {
        // The dump carries one run's series; with several configs the last
        // one wins (series dumps are a per-run drill-down, not a fleet view).
        let Some((label, report)) = reports.last() else {
            eprintln!("--series-out: no run produced a report");
            return 1;
        };
        if reports.len() > 1 {
            eprintln!("--series-out: multiple configs given; dumping the last ({label})");
        }
        if let Err(e) = std::fs::write(&path, arcus::obs::dump::write(&report.obs)) {
            eprintln!("writing {}: {e}", path.display());
            return 1;
        }
        eprintln!("wrote {}", path.display());
    }
    0
}

/// `arcus top`: decode a `simulate --series-out` dump and print the worst
/// flows / tenants by SLO attainment and window p99.
fn top(args: &[String]) -> i32 {
    let mut limit = 10usize;
    let mut file: Option<PathBuf> = None;
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--limit" {
            match args.get(i + 1).and_then(|v| v.parse::<usize>().ok()) {
                Some(n) if n > 0 => limit = n,
                _ => {
                    eprintln!("--limit needs a positive integer");
                    return 2;
                }
            }
            i += 2;
        } else if file.is_none() {
            file = Some(PathBuf::from(&args[i]));
            i += 1;
        } else {
            eprintln!("unexpected argument `{}`", args[i]);
            return 2;
        }
    }
    let Some(file) = file else {
        eprintln!("usage: arcus top <series.bin> [--limit N]");
        return 2;
    };
    let buf = match std::fs::read(&file) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("{}: {e}", file.display());
            return 1;
        }
    };
    match arcus::obs::dump::read(&buf) {
        Ok(data) => {
            print!("{}", arcus::obs::top::render_top(&data, limit));
            0
        }
        Err(e) => {
            eprintln!("{}: {e}", file.display());
            1
        }
    }
}

/// `arcus bench`: run the committed perf presets on the chosen event-queue
/// disciplines, write `BENCH_<preset>.json` files (+ an optional combined
/// `--out` file), and gate on the committed events/sec floor. See
/// `rust/src/perf/mod.rs` for the presets and JSON schema.
fn bench(args: &[String]) -> i32 {
    use arcus::perf::{self, QueueKind};

    let mut preset_names: Option<Vec<&str>> = None;
    let mut queues = vec![QueueKind::Heap, QueueKind::Calendar, QueueKind::Wheel];
    let mut out: Option<PathBuf> = None;
    let mut floor_path: Option<PathBuf> = None;
    let mut write_files = true;
    let mut quick = false;
    let mut verify = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--quick" => {
                quick = true;
                i += 1;
            }
            "--no-files" => {
                write_files = false;
                i += 1;
            }
            "--verify" => {
                verify = true;
                i += 1;
            }
            "--preset" => {
                let Some(v) = args.get(i + 1) else {
                    eprintln!(
                        "--preset needs a value (small|medium|large|xlarge|fleet|population|all)"
                    );
                    return 2;
                };
                if v == "all" {
                    preset_names =
                        Some(vec!["small", "medium", "large", "xlarge", "fleet", "population"]);
                } else if let Some(p) = arcus::perf::preset_by_name(v) {
                    preset_names = Some(vec![p.name]);
                } else {
                    eprintln!(
                        "unknown preset `{v}` (valid: small, medium, large, xlarge, fleet, \
                         population, all)"
                    );
                    return 2;
                }
                i += 2;
            }
            "--queue" => {
                let Some(v) = args.get(i + 1) else {
                    eprintln!("--queue needs a value (heap|calendar|wheel|both|all)");
                    return 2;
                };
                match QueueKind::parse(v) {
                    Ok(q) => queues = q,
                    Err(e) => {
                        eprintln!("{e}");
                        return 2;
                    }
                }
                i += 2;
            }
            "--out" => {
                let Some(v) = args.get(i + 1) else {
                    eprintln!("--out needs a file path");
                    return 2;
                };
                out = Some(PathBuf::from(v));
                i += 2;
            }
            "--floor" => {
                let Some(v) = args.get(i + 1) else {
                    eprintln!("--floor needs a perf_floor.toml path");
                    return 2;
                };
                floor_path = Some(PathBuf::from(v));
                i += 2;
            }
            other => {
                eprintln!("unknown flag `{other}`");
                return 2;
            }
        }
    }

    // `--quick` is CI-sized (small preset only) but an explicit `--preset`
    // wins regardless of flag order. The 10k-flow `xlarge`, multi-host
    // `fleet`, and 100k-user `population` presets run only when named
    // (alone or via `all`).
    let preset_names = match preset_names {
        Some(names) => names,
        None if quick => vec!["small"],
        None => vec!["small", "medium", "large"],
    };

    // The allocation ceiling is shared across presets; it only bites when
    // the binary was built with `--features bench-alloc` (otherwise
    // allocs_per_event is 0.0 = unmeasured and the gate skips).
    let alloc_ceiling = match &floor_path {
        Some(path) => match perf::load_alloc_ceiling(path) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("{e:#}");
                return 1;
            }
        },
        None => None,
    };
    println!("preset   queue         events        ev/s      wall(ms)  wall/sim  peakq    rss(KB)  allocs/ev");
    let mut all = Vec::new();
    let mut floor_violated = false;
    let mut verify_failed = false;
    for name in &preset_names {
        let p = perf::preset_by_name(name).expect("preset names are pre-validated");
        // Floors may be committed per preset (the 10k-flow scenario has a
        // different per-event cost profile than the flat ones).
        let floor = match &floor_path {
            Some(path) => match perf::load_floor_for(path, p.name) {
                Ok(f) => Some(f),
                Err(e) => {
                    eprintln!("{e:#}");
                    return 1;
                }
            },
            None => None,
        };
        let mut per_preset = Vec::new();
        let mut canonicals: Vec<(&'static str, String)> = Vec::new();
        for &q in &queues {
            let r = if verify {
                let (r, report) = perf::run_preset_report(&p, q);
                canonicals.push((r.queue, report.canonical()));
                r
            } else {
                perf::run_preset(&p, q)
            };
            println!(
                "{:<8} {:<11} {:>9} {:>12.0} {:>11.1} {:>9.2} {:>6} {:>10} {:>10}",
                r.scenario,
                r.queue,
                r.events_executed,
                r.events_per_sec,
                r.wall_ms,
                r.wall_ms_per_sim_ms(),
                r.peak_queue_depth,
                r.rss_hint_kb,
                if r.allocs_per_event > 0.0 {
                    format!("{:.4}", r.allocs_per_event)
                } else {
                    "-".to_string()
                },
            );
            if let Some(f) = floor {
                if r.events_per_sec < f {
                    eprintln!(
                        "FLOOR VIOLATION: {} on {} ran {:.0} ev/s < committed floor {:.0}",
                        r.scenario, r.queue, r.events_per_sec, f
                    );
                    floor_violated = true;
                }
            }
            if let Some(c) = alloc_ceiling {
                if r.allocs_per_event > 0.0 && r.allocs_per_event > c {
                    eprintln!(
                        "ALLOC CEILING VIOLATION: {} on {} made {:.4} allocs/event \
                         > committed ceiling {:.4}",
                        r.scenario, r.queue, r.allocs_per_event, c
                    );
                    floor_violated = true;
                }
            }
            per_preset.push(r.clone());
            all.push(r);
        }
        // `--verify`: every queue discipline must produce a byte-identical
        // canonical report for this preset (the determinism contract at
        // bench scale — 10k flows included).
        if verify {
            if let Some((q0, c0)) = canonicals.first() {
                for (q, c) in &canonicals[1..] {
                    if c != c0 {
                        eprintln!(
                            "VERIFY FAILED: {} canonical reports differ between {q0} and {q}",
                            p.name
                        );
                        verify_failed = true;
                    }
                }
                if !verify_failed {
                    eprintln!(
                        "verified: {} canonical report byte-identical across {} queue(s)",
                        p.name,
                        canonicals.len()
                    );
                }
            }
        }
        if write_files {
            let file = format!("BENCH_{}.json", p.name);
            if let Err(e) = std::fs::write(&file, perf::to_json(&per_preset)) {
                eprintln!("writing {file}: {e}");
                return 1;
            }
            eprintln!("wrote {file}");
        }
    }
    // Closed-loop overhead profile: when the floor file commits
    // `min_adaptive_ev_ratio`, run the profile preset twice on the
    // reference heap — static planner vs adaptive control plane — and
    // gate the adaptive run's events/sec as a fraction of the static
    // run's. The ratio is self-relative (both runs share the process and
    // allocator), so it tolerates runner speed, unlike absolute floors.
    if let Some(path) = &floor_path {
        let ratio = match perf::load_adaptive_ratio(path) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("{e:#}");
                return 1;
            }
        };
        if let Some(ratio) = ratio {
            let (st, ad) = perf::run_adaptive_profile();
            for r in [&st, &ad] {
                println!(
                    "{:<8} {:<11} {:>9} {:>12.0} {:>11.1} {:>9.2} {:>6} {:>10} {:>10}",
                    r.scenario,
                    r.queue,
                    r.events_executed,
                    r.events_per_sec,
                    r.wall_ms,
                    r.wall_ms_per_sim_ms(),
                    r.peak_queue_depth,
                    r.rss_hint_kb,
                    if r.allocs_per_event > 0.0 {
                        format!("{:.4}", r.allocs_per_event)
                    } else {
                        "-".to_string()
                    },
                );
            }
            let measured = if st.events_per_sec > 0.0 {
                ad.events_per_sec / st.events_per_sec
            } else {
                0.0
            };
            if measured < ratio {
                eprintln!(
                    "ADAPTIVE RATIO VIOLATION: closed loop ran {:.0} ev/s vs static \
                     {:.0} ({measured:.3} < committed min ratio {ratio:.3})",
                    ad.events_per_sec, st.events_per_sec
                );
                floor_violated = true;
            } else {
                eprintln!(
                    "adaptive profile: {measured:.3}x static events/sec (floor {ratio:.3})"
                );
            }
            all.push(st);
            all.push(ad);
            if write_files {
                let file = "BENCH_adaptive.json";
                if let Err(e) = std::fs::write(file, perf::to_json(&all[all.len() - 2..])) {
                    eprintln!("writing {file}: {e}");
                    return 1;
                }
                eprintln!("wrote {file}");
            }
        }
    }
    if let Some(path) = out {
        if let Err(e) = std::fs::write(&path, perf::to_json(&all)) {
            eprintln!("writing {}: {e}", path.display());
            return 1;
        }
        eprintln!("wrote {}", path.display());
    }
    if floor_violated || verify_failed {
        return 1;
    }
    0
}

/// `arcus sweep`: expand a scenario grid over the requested axes, run every
/// scenario across worker threads, and print the per-axis comparison
/// tables. Defaults give a 3-mode × 3-tenant-count × 2-mix × 2-burst ×
/// 2-seed grid (72 scenarios) in a few seconds.
fn sweep(args: &[String]) -> i32 {
    let mut modes = vec![Mode::Arcus, Mode::HostNoTs, Mode::BypassedPanic];
    let mut tenants = vec![1usize, 2, 4];
    let mut mixes = vec![SizeMix::Mtu, SizeMix::Bulk];
    let mut bursts = vec![Burstiness::Paced, Burstiness::Poisson];
    let mut tightness = vec![0.7f64];
    let mut churn = vec![Churn::Static];
    let mut faults = vec![FaultProfile::Healthy];
    let mut scale = vec![Scale::Flat];
    let mut control = vec![ControlKind::Static];
    let mut hosts = vec![1usize];
    let mut population: Vec<Option<usize>> = vec![None];
    let mut accel_names = vec!["ipsec".to_string()];
    let mut seeds = vec![1u64, 2];
    let mut duration_ms = 5u64;
    let mut load = 0.9f64;
    let mut threads: Option<usize> = None;
    let mut long_form = false;
    let mut expect_flows: Option<usize> = None;
    let mut prom_out: Option<PathBuf> = None;

    let mut i = 0;
    while i < args.len() {
        let flag = args[i].as_str();
        if flag == "--scenarios" {
            long_form = true;
            i += 1;
            continue;
        }
        let Some(value) = args.get(i + 1) else {
            eprintln!("flag `{flag}` needs a value");
            return 2;
        };
        let parts: Vec<&str> = value.split(',').filter(|s| !s.is_empty()).collect();
        if parts.is_empty() {
            eprintln!("flag `{flag}` got an empty value");
            return 2;
        }
        match flag {
            "--modes" => {
                modes.clear();
                for p in &parts {
                    match Mode::parse(p) {
                        Ok(m) => modes.push(m),
                        Err(e) => {
                            eprintln!("{e}");
                            return 2;
                        }
                    }
                }
            }
            "--tenants" => {
                tenants.clear();
                for p in &parts {
                    match p.parse::<usize>() {
                        Ok(n) if n > 0 => tenants.push(n),
                        _ => {
                            eprintln!("bad tenant count `{p}` (positive integers only)");
                            return 2;
                        }
                    }
                }
            }
            "--mixes" => {
                mixes.clear();
                for p in &parts {
                    match SizeMix::parse(p) {
                        Ok(m) => mixes.push(m),
                        Err(e) => {
                            eprintln!("{e}");
                            return 2;
                        }
                    }
                }
            }
            "--bursts" => {
                bursts.clear();
                for p in &parts {
                    match parse_burst(p) {
                        Ok(b) => bursts.push(b),
                        Err(e) => {
                            eprintln!("{e}");
                            return 2;
                        }
                    }
                }
            }
            "--tightness" => {
                tightness.clear();
                for p in &parts {
                    match p.parse::<f64>() {
                        Ok(x) if x > 0.0 => tightness.push(x),
                        _ => {
                            eprintln!("bad tightness `{p}` (positive numbers only)");
                            return 2;
                        }
                    }
                }
            }
            "--churn" => {
                churn.clear();
                for p in &parts {
                    match Churn::parse(p) {
                        Ok(c) => churn.push(c),
                        Err(e) => {
                            eprintln!("{e}");
                            return 2;
                        }
                    }
                }
            }
            "--faults" => {
                faults.clear();
                for p in &parts {
                    match FaultProfile::parse(p) {
                        Ok(f) => faults.push(f),
                        Err(e) => {
                            eprintln!("{e}");
                            return 2;
                        }
                    }
                }
            }
            "--flows" => {
                scale.clear();
                for p in &parts {
                    match Scale::parse(p) {
                        Ok(s) => scale.push(s),
                        Err(e) => {
                            eprintln!("{e}");
                            return 2;
                        }
                    }
                }
            }
            "--control" => {
                control.clear();
                for p in &parts {
                    match ControlKind::parse(p) {
                        Ok(c) => control.push(c),
                        Err(e) => {
                            eprintln!("{e}");
                            return 2;
                        }
                    }
                }
            }
            "--hosts" => {
                hosts.clear();
                for p in &parts {
                    match p.parse::<usize>() {
                        Ok(n) if n > 0 => hosts.push(n),
                        _ => {
                            eprintln!("bad host count `{p}` (positive integers only)");
                            return 2;
                        }
                    }
                }
            }
            "--population" => {
                population.clear();
                for p in &parts {
                    match p.parse::<usize>() {
                        // `0` = the legacy per-flow pattern generators; CI's
                        // byte-identity gate compares `--population 0` cells
                        // against a no-flag sweep.
                        Ok(0) => population.push(None),
                        Ok(n) => population.push(Some(n)),
                        Err(_) => {
                            eprintln!(
                                "bad population `{p}` (user counts; 0 = pattern generators)"
                            );
                            return 2;
                        }
                    }
                }
            }
            "--accels" => {
                accel_names = parts.iter().map(|s| s.to_string()).collect();
            }
            "--seeds" => {
                seeds.clear();
                for p in &parts {
                    match p.parse::<u64>() {
                        Ok(s) => seeds.push(s),
                        Err(_) => {
                            eprintln!("bad seed `{p}`");
                            return 2;
                        }
                    }
                }
            }
            "--duration-ms" => match value.parse::<u64>() {
                Ok(d) if d > 0 => duration_ms = d,
                _ => {
                    eprintln!("bad duration `{value}`");
                    return 2;
                }
            },
            "--load" => match value.parse::<f64>() {
                Ok(l) if l > 0.0 => load = l,
                _ => {
                    eprintln!("bad load `{value}`");
                    return 2;
                }
            },
            "--threads" => match value.parse::<usize>() {
                Ok(t) if t > 0 => threads = Some(t),
                _ => {
                    eprintln!("bad thread count `{value}`");
                    return 2;
                }
            },
            "--expect-flows" => match value.parse::<usize>() {
                Ok(n) => expect_flows = Some(n),
                _ => {
                    eprintln!("bad --expect-flows value `{value}`");
                    return 2;
                }
            },
            "--prom-out" => prom_out = Some(PathBuf::from(value)),
            other => {
                eprintln!("unknown flag `{other}`");
                return 2;
            }
        }
        i += 2;
    }

    let mut accels = Vec::new();
    for n in &accel_names {
        match AccelModel::by_name(n) {
            Some(m) => accels.push(m),
            None => {
                eprintln!("unknown accelerator `{n}` (see `arcus modes`)");
                return 2;
            }
        }
    }

    // Tightness values are labeled at 4 decimals; values that collide
    // there would silently merge into one aggregate row.
    let mut seen = std::collections::HashSet::new();
    for &t in &tightness {
        if !seen.insert(format!("{t:.4}")) {
            eprintln!("tightness values collide at 4 decimals ({t:.4}); space them further apart");
            return 2;
        }
    }

    let grid = SweepGrid::new(GridBase {
        duration: duration_ms * MILLIS,
        warmup: (duration_ms * MILLIS / 5).max(MILLIS / 2),
        line_rate: Rate::gbps(32.0),
        load,
        path: Path::FunctionCall,
        seed: 1,
    })
    .modes(modes)
    .tenants(tenants)
    .mixes(mixes)
    .bursts(bursts)
    .tightness(tightness)
    .churn(churn)
    .faults(faults)
    .scale(scale)
    .control(control)
    .hosts(hosts)
    .population(population)
    .accels(accels)
    .seeds(seeds);

    if let Err(e) = grid.validate() {
        eprintln!("invalid sweep grid: {e}");
        return 2;
    }

    let runner = match threads {
        Some(t) => SweepRunner::with_threads(t),
        None => SweepRunner::new(),
    };
    // Progress goes to stderr: stdout carries only the deterministic
    // tables, so `sweep --threads 1 > a` / `--threads 8 > b` diff clean.
    eprintln!(
        "expanding {} scenarios ({} workers) ...",
        grid.cardinality(),
        runner.threads()
    );
    let outcomes = runner.run(&grid);
    // Loud emptiness check for CI smoke steps: a sweep that silently
    // produced nothing (or fewer flow rows than the grid implies) must
    // fail even though the process would otherwise exit 0.
    if let Some(n) = expect_flows {
        let total: usize = outcomes.iter().map(|o| o.report.per_flow.len()).sum();
        if total < n {
            eprintln!("expected at least {n} flow reports across the sweep, got {total}");
            return 1;
        }
    }
    if let Some(path) = &prom_out {
        // One scenario label per grid cell; expansion order keeps the file
        // deterministic across thread counts.
        let labeled: Vec<(String, &arcus::system::SystemReport)> = outcomes
            .iter()
            .map(|o| (o.key.label(), &o.report))
            .collect();
        if let Err(e) = std::fs::write(path, arcus::obs::prom::render(&labeled)) {
            eprintln!("writing {}: {e}", path.display());
            return 1;
        }
        eprintln!("wrote {}", path.display());
    }
    let agg = aggregate(&outcomes);
    if long_form {
        print!("{}", agg.render_scenarios());
        println!();
    }
    print!("{}", agg.render());
    0
}

/// `arcus trace`: record a population config's arrival trace to a compact
/// varint binary file, or replay one back through the engine. Record never
/// runs the engine — it enumerates the same generators the engine would
/// pull from — so `record | replay --verify` is the determinism gate for
/// the whole trace path.
fn trace_cmd(args: &[String]) -> i32 {
    let usage = || {
        eprintln!(
            "usage: arcus trace record <config.toml> --out <trace.bin>\n       \
             arcus trace replay <config.toml> <trace.bin> [--verify]"
        );
        2
    };
    let load_spec = |path: &PathBuf| -> Result<ExperimentSpec, i32> {
        let doc = Document::from_file(path).map_err(|e| {
            eprintln!("{}: {e:#}", path.display());
            1
        })?;
        spec_from_document(&doc).map_err(|e| {
            eprintln!("{}: {e:#}", path.display());
            1
        })
    };
    match args.first().map(String::as_str) {
        Some("record") => {
            let mut config: Option<PathBuf> = None;
            let mut out: Option<PathBuf> = None;
            let mut i = 1;
            while i < args.len() {
                if args[i] == "--out" {
                    let Some(v) = args.get(i + 1) else {
                        eprintln!("--out needs a file path");
                        return 2;
                    };
                    out = Some(PathBuf::from(v));
                    i += 2;
                } else if config.is_none() {
                    config = Some(PathBuf::from(&args[i]));
                    i += 1;
                } else {
                    eprintln!("unexpected argument `{}`", args[i]);
                    return 2;
                }
            }
            let (Some(config), Some(out)) = (config, out) else {
                return usage();
            };
            let spec = match load_spec(&config) {
                Ok(s) => s,
                Err(code) => return code,
            };
            let records = match arcus::system::record_population_trace(&spec) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("{}: {e}", config.display());
                    return 1;
                }
            };
            let users = spec.population.as_ref().map(|c| c.users as u64).unwrap_or(0);
            let buf = match arcus::workload::trace::write(
                users,
                spec.flows.len() as u64,
                &records,
            ) {
                Ok(b) => b,
                Err(e) => {
                    eprintln!("encoding trace: {e}");
                    return 1;
                }
            };
            if let Err(e) = std::fs::write(&out, &buf) {
                eprintln!("writing {}: {e}", out.display());
                return 1;
            }
            println!(
                "recorded {} arrivals ({} users, {} flows) to {} ({} bytes)",
                records.len(),
                users,
                spec.flows.len(),
                out.display(),
                buf.len()
            );
            0
        }
        Some("replay") => {
            let mut verify = false;
            let mut paths: Vec<PathBuf> = Vec::new();
            for a in &args[1..] {
                if a == "--verify" {
                    verify = true;
                } else {
                    paths.push(PathBuf::from(a));
                }
            }
            let [config, trace_path] = paths.as_slice() else {
                return usage();
            };
            let spec = match load_spec(config) {
                Ok(s) => s,
                Err(code) => return code,
            };
            let buf = match std::fs::read(trace_path) {
                Ok(b) => b,
                Err(e) => {
                    eprintln!("{}: {e}", trace_path.display());
                    return 1;
                }
            };
            let data = match arcus::workload::trace::read(&buf) {
                Ok(d) => d,
                Err(e) => {
                    eprintln!("{}: {e}", trace_path.display());
                    return 1;
                }
            };
            let report = match arcus::system::run_replay(&spec, &data) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("{e}");
                    return 1;
                }
            };
            println!("=== replay: {} ({} arrivals) ===", trace_path.display(), data.records.len());
            print!("{}", report.render());
            if verify {
                // The gate: a replayed run must be indistinguishable from
                // the generator-driven run it was recorded from.
                let live = run(&spec);
                if live.canonical() != report.canonical() {
                    eprintln!(
                        "VERIFY FAILED: replayed canonical report differs from the generator run"
                    );
                    return 1;
                }
                eprintln!(
                    "verified: replayed canonical report byte-identical to the generator run"
                );
            }
            0
        }
        _ => usage(),
    }
}

/// `arcus churn`: tenant-churn walkthrough on one shared IPSec engine
/// (~26 Gbps effective at MTU, ~24.6 Gbps admission budget). Every
/// lifecycle decision — admission, rejection, departure, renegotiation —
/// crosses the control-plane API; the incumbents' SLOs hold throughout.
fn churn() -> i32 {
    let line = Rate::gbps(32.0);
    let flow = |id: usize, slo: f64| {
        FlowSpec::new(
            id,
            id,
            Path::FunctionCall,
            TrafficPattern::fixed(1500, 0.4, line),
            Slo::gbps(slo),
            0,
        )
    };
    let base = |flows: Vec<FlowSpec>| {
        ExperimentSpec::new(Mode::Arcus, vec![AccelModel::ipsec_32g()], flows)
            .with_duration(10 * MILLIS)
            .with_warmup(MILLIS)
    };
    let print_flows = |report: &arcus::system::SystemReport| {
        println!("flow  slo(G)  fate       arrive(ms)  goodput(G)  attain  p99(us)");
        for f in &report.per_flow {
            let fate = if f.rejected {
                "REJECTED"
            } else if f.departed_at.is_some() {
                "departed"
            } else {
                "admitted"
            };
            let slo = match f.slo {
                Slo::Throughput { target, .. } => target.as_gbps(),
                _ => 0.0,
            };
            println!(
                "{:>4} {:>7.1}  {:<9} {:>10.1} {:>11.2} {:>7} {:>8.2}",
                f.flow,
                slo,
                fate,
                f.arrived_at as f64 / MILLIS as f64,
                f.goodput.as_gbps(),
                f.slo_attainment()
                    .map(|a| format!("{:.2}", a))
                    .unwrap_or_else(|| "-".to_string()),
                f.lat_p99 as f64 / 1e6,
            );
        }
    };

    println!("One 32 Gbps IPSec engine; admission budget ≈ 24.6 Gbps at MTU.\n");

    println!("=== Act 1: a tenant joins mid-run, within capacity ===");
    println!("Incumbents hold 9 + 8 Gbps; tenant 2 asks for 6 Gbps at t = 4 ms.");
    let spec = base(vec![flow(0, 9.0), flow(1, 8.0), flow(2, 6.0)])
        .with_event(LifecycleEvent::Arrive { flow: 2, at: 4 * MILLIS });
    print_flows(&run(&spec));
    println!("→ admitted: 9 + 8 + 6 fits the budget; incumbents stay on SLO.\n");

    println!("=== Act 2: an over-greedy tenant is rejected ===");
    println!("Same incumbents; tenant 2 asks for 10 Gbps (9 + 8 + 10 > 24.6).");
    let spec = base(vec![flow(0, 9.0), flow(1, 8.0), flow(2, 10.0)])
        .with_event(LifecycleEvent::Arrive { flow: 2, at: 4 * MILLIS });
    print_flows(&run(&spec));
    println!("→ rejected by capacity planning; incumbents keep their tails.\n");

    println!("=== Act 3: a departure releases capacity a later arrival claims ===");
    println!("Tenants 0/1 hold 10 + 10; tenant 0 departs at 4 ms; tenant 2");
    println!("asks for 10 Gbps at 6 ms — inadmissible before the departure.");
    let spec = base(vec![flow(0, 10.0), flow(1, 10.0), flow(2, 10.0)])
        .with_event(LifecycleEvent::Depart { flow: 0, at: 4 * MILLIS })
        .with_event(LifecycleEvent::Arrive { flow: 2, at: 6 * MILLIS });
    print_flows(&run(&spec));
    println!("→ the freed 10 Gbps admits tenant 2; nothing was re-planned by hand.\n");

    println!("=== Act 4: mid-run SLO renegotiation ===");
    println!("Tenant 0 renegotiates 8 → 12 Gbps at t = 5 ms (12 + 8 fits).");
    let spec = base(vec![flow(0, 8.0), flow(1, 8.0)]).with_event(
        LifecycleEvent::Renegotiate { flow: 0, at: 5 * MILLIS, slo: Slo::gbps(12.0) },
    );
    let report = run(&spec);
    print_flows(&report);
    println!(
        "→ accepted ({} rejected renegotiations); the shaper was reprogrammed",
        report.per_flow[0].renegotiations_rejected
    );
    println!("  ~10 µs after the decision, without stalling the dataplane.");
    0
}

/// `arcus chaos`: fault-injection walkthrough — the same shared IPSec
/// engine as `arcus churn`, but the hardware and the tenants misbehave.
/// Every act prints the per-era attainment table plus the recovery-time
/// metric (time from the heal until a tenant's control-period windows
/// carry ≥ 95% of its SLO again).
fn chaos() -> i32 {
    let line = Rate::gbps(32.0);
    let flow = |id: usize, slo: f64, load: f64| {
        FlowSpec::new(
            id,
            id,
            Path::FunctionCall,
            TrafficPattern::fixed(1500, load, line),
            if slo > 0.0 { Slo::gbps(slo) } else { Slo::BestEffort },
            0,
        )
    };
    let base = |flows: Vec<FlowSpec>| {
        ExperimentSpec::new(Mode::Arcus, vec![AccelModel::ipsec_32g()], flows)
            .with_duration(12 * MILLIS)
            .with_warmup(2 * MILLIS)
    };

    println!("One 32 Gbps IPSec engine; three tenants holding 9 + 8 Gbps + best-effort.\n");

    println!("=== Act 1: the accelerator degrades to 50% for 3 ms ===");
    let spec = base(vec![flow(0, 9.0, 0.45), flow(1, 8.0, 0.45), flow(2, 0.0, 0.5)])
        .with_fault(FaultSpec::new(
            FaultKind::AccelSlowdown { unit: 0, factor: 0.5 },
            4 * MILLIS,
            7 * MILLIS,
        ));
    let report = run(&spec);
    print!("{}", report.render_fault_eras());
    println!("→ attainment dips during the window; the control plane compensates and");
    println!("  both committed tenants are back on SLO within the recovery times above.\n");

    println!("=== Act 2: an adversarial tenant ignores its shaper ===");
    println!("The best-effort tenant floods 4 KB messages unshaped at t = 4 ms; the");
    println!("BE-refresh reaction clamps it at the interface within a few control");
    println!("periods.");
    let rogue = FlowSpec::new(
        2,
        2,
        Path::FunctionCall,
        TrafficPattern::fixed(4096, 0.6, line),
        Slo::BestEffort,
        0,
    );
    let spec = base(vec![flow(0, 9.0, 0.45), flow(1, 8.0, 0.45), rogue])
        .with_fault(FaultSpec::new(
            FaultKind::RogueTenant { flow: 2 },
            4 * MILLIS,
            9 * MILLIS,
        ));
    let report = run(&spec);
    print!("{}", report.render_fault_eras());
    let reconfigs = report.per_flow[2].reconfigs;
    println!("→ the rogue bucket was re-armed {reconfigs} time(s); committed SLOs held.\n");

    println!("=== Act 3: the profile table lies (capacity over-estimated 1.6x) ===");
    println!("A third committed tenant is admitted against the skewed table at 6 ms;");
    println!("re-profiling heals the table at 8 ms and the over-commit reconciliation");
    println!("clamps every tenant to its true proportional share.");
    let spec = base(vec![flow(0, 9.0, 0.45), flow(1, 8.0, 0.45), flow(2, 10.0, 0.45)])
        .with_event(LifecycleEvent::Arrive { flow: 2, at: 6 * MILLIS })
        .with_fault(FaultSpec::new(
            FaultKind::ProfileSkew { accel: 0, factor: 1.6 },
            5 * MILLIS,
            8 * MILLIS,
        ));
    let report = run(&spec);
    print!("{}", report.render());
    let admitted = !report.per_flow[2].rejected;
    println!(
        "→ tenant 2 {} under the skew; after the heal the programmed rates were",
        if admitted { "was admitted" } else { "was rejected even so" }
    );
    println!("  rebalanced (9 + 8 + 10 > the true ~24.6 Gbps budget — nobody may boost).");
    0
}

/// `arcus fleet`: the multi-host walkthrough. The same sharded world runs
/// twice — once with instant directive distribution, once with a
/// propagation delay plus a drop window covering the fault — so the cost
/// of stale fleet config is visible as fault-era attainment loss.
fn fleet(args: &[String]) -> i32 {
    use arcus::fleet::{run as fleet_run, FleetConfig};
    use arcus::util::units::MICROS;

    let mut hosts = 2usize;
    let mut delay_us = 500u64;
    let mut i = 0;
    while i < args.len() {
        let flag = args[i].as_str();
        let Some(value) = args.get(i + 1) else {
            eprintln!("flag `{flag}` needs a value");
            return 2;
        };
        match flag {
            "--hosts" => match value.parse::<usize>() {
                Ok(n) if (1..=64).contains(&n) => hosts = n,
                _ => {
                    eprintln!("bad host count `{value}` (1..=64)");
                    return 2;
                }
            },
            "--delay-us" => match value.parse::<u64>() {
                Ok(d) => delay_us = d,
                _ => {
                    eprintln!("bad delay `{value}`");
                    return 2;
                }
            },
            other => {
                eprintln!("unknown flag `{other}`");
                return 2;
            }
        }
        i += 2;
    }

    let line = Rate::gbps(32.0);
    let tenants = hosts * 2;
    // Two flows per tenant, striped over two IPSec engines per host:
    // each host carries 2 tenants × 2 flows, 8 G committed per engine —
    // inside the ~24.6 G budget, but offered load oversubscribes it so
    // shaping (and the fleet envelopes) bind.
    let flows: Vec<FlowSpec> = (0..tenants * 2)
        .map(|i| {
            FlowSpec::new(
                i,
                i / 2,
                Path::FunctionCall,
                TrafficPattern::fixed(1500, 0.45, line),
                Slo::gbps(8.0),
                i % 2,
            )
        })
        .collect();
    let template = ExperimentSpec::new(
        Mode::Arcus,
        vec![AccelModel::ipsec_32g(), AccelModel::ipsec_32g()],
        flows,
    )
    .with_duration(12 * MILLIS)
    .with_warmup(2 * MILLIS)
    .with_hierarchy()
    .with_fault(FaultSpec::new(
        FaultKind::AccelSlowdown { unit: 0, factor: 0.5 },
        4 * MILLIS,
        7 * MILLIS,
    ));

    println!(
        "{hosts} host(s), {tenants} tenants, {} flows; host 0's engine 0 degrades to 50%",
        template.flows.len()
    );
    println!("for 3 ms. The fleet tier distributes tenant envelopes as versioned,");
    println!("ACKed deltas; run B delays them by {delay_us} us and drops every delivery");
    println!("inside the fault window, so hosts run on stale config exactly when");
    println!("the boost matters.\n");

    println!("=== Run A: instant distribution ===");
    let fresh = fleet_run(
        &template,
        &FleetConfig { hosts, ..FleetConfig::default() },
    );
    print!("{}", fresh.render_fault_eras());
    println!(
        "→ staleness_max = {} us, per-host rollups: {}\n",
        fresh.directive_staleness_max / MICROS,
        fresh.host_rollups.len()
    );

    println!("=== Run B: {delay_us} us propagation + drop window over the fault ===");
    let stale = fleet_run(
        &template,
        &FleetConfig {
            hosts,
            propagation_delay: delay_us * MICROS,
            drop_windows: vec![(4 * MILLIS, 7 * MILLIS)],
            ..FleetConfig::default()
        },
    );
    print!("{}", stale.render_fault_eras());
    println!(
        "→ staleness_max = {} us (vs {} us in run A): boost envelopes arrived",
        stale.directive_staleness_max / MICROS,
        fresh.directive_staleness_max / MICROS
    );
    println!("  late, so catch-up ran at the tight ceiling for longer.");
    0
}

fn profile(names: &[String]) -> i32 {
    let names: Vec<&str> = if names.is_empty() {
        vec!["ipsec", "aes128", "sha1hmac", "compress"]
    } else {
        names.iter().map(String::as_str).collect()
    };
    let mut models = Vec::new();
    for n in &names {
        match AccelModel::by_name(n) {
            Some(m) => models.push(m),
            None => {
                eprintln!("unknown accelerator `{n}` (see `arcus modes`)");
                return 2;
            }
        }
    }
    let table = ProfileTable::learn(&models, &FabricConfig::gen3_x8());
    println!("Capacity(t, X, N) — offline profile (Gbps; V = SLO-Violating tag):\n");
    for m in &models {
        println!("[{}] (paths × sizes, n_flows = 2)", m.name);
        print!("{:<16}", "path \\ size");
        for s in arcus::coordinator::profile::SIZE_BUCKETS {
            print!(" {:>8}", if s >= 1024 { format!("{}K", s / 1024) } else { format!("{s}B") });
        }
        println!();
        for path in Path::ALL {
            print!("{:<16}", path.name());
            for s in arcus::coordinator::profile::SIZE_BUCKETS {
                let e = table.capacity(m.name, path, s, 2).unwrap();
                print!(
                    " {:>7.1}{}",
                    e.capacity.as_gbps(),
                    if e.slo_friendly { " " } else { "V" }
                );
            }
            println!();
        }
        println!();
    }
    0
}

fn serve(args: &[String]) -> i32 {
    use arcus::server::{Output, Server, ServerConfig, Work};
    let mut artifacts = PathBuf::from("artifacts");
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--artifacts" if i + 1 < args.len() => {
                artifacts = PathBuf::from(&args[i + 1]);
                i += 2;
            }
            other => {
                eprintln!("unknown flag `{other}`");
                return 2;
            }
        }
    }
    println!("starting PJRT serving runtime from {} ...", artifacts.display());
    let server = match Server::start(
        ServerConfig::new(&artifacts)
            .tenant("gold", Some(40e6)) // 40 MB/s reserved
            .tenant("bronze", Some(10e6)), // 10 MB/s reserved
    ) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("failed to start: {e:#}");
            return 1;
        }
    };
    println!("engine up ({} tenants). running a 3 s demo load ...\n", 2);
    let t0 = std::time::Instant::now();
    let mut ok = [0u64; 2];
    let mut i = 0u32;
    while t0.elapsed().as_secs_f64() < 3.0 {
        let mut rxs = Vec::new();
        for tenant in 0..2 {
            rxs.push((tenant, server.submit(
                tenant,
                Work::EncryptDigest {
                    data: vec![0x5A; 4096],
                    key: [1; 8],
                    nonce: [2; 3],
                    counter0: i.wrapping_mul(64),
                },
            )));
            i += 1;
        }
        for (tenant, rx) in rxs {
            if let Ok(r) = rx.recv() {
                if !matches!(r.output, Output::Rejected(_)) {
                    ok[tenant] += 1;
                }
            }
        }
    }
    let stats = server.stats();
    println!("tenant   completed   goodput      p50        p99");
    for (t, s) in stats.tenants.iter().enumerate() {
        println!(
            "{:<8} {:>9} {:>8.2}MB/s {:>8.1}µs {:>8.1}µs",
            if t == 0 { "gold" } else { "bronze" },
            s.completed,
            s.goodput() / 1e6,
            s.latency_ns.percentile(50.0) as f64 / 1e3,
            s.latency_ns.percentile(99.0) as f64 / 1e3,
        );
    }
    println!(
        "\nbatches: {}  mean group fill: {:.1}",
        stats.batches,
        stats.mean_group_fill()
    );
    println!("gold is shaped to 4× bronze's rate — the provider's registers decide, not luck.");
    server.shutdown();
    let _ = ok;
    0
}
