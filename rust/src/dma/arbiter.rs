//! Multi-queue arbiter with pluggable scheduling policies.
//!
//! Policies:
//! - [`Policy::RoundRobin`] — per-message RR (the SR-IOV arbiter of §5.1).
//!   Message-blind: byte share follows message size, which is exactly how
//!   large-message flows "steal" bandwidth in Fig 8.
//! - [`Policy::WeightedRoundRobin`] — messages proportional to weight.
//! - [`Policy::Priority`] — strict priority (PANIC's high-priority class).
//! - [`Policy::DeficitRoundRobin`] — byte-accurate weighted fair queueing
//!   (PANIC's WFQ approximation); fair in *bytes*, not messages.

use std::collections::VecDeque;

/// Scheduling policy for an [`Arbiter`].
#[derive(Debug, Clone, PartialEq)]
pub enum Policy {
    RoundRobin,
    /// weights[i] messages per cycle for queue i.
    WeightedRoundRobin(Vec<u32>),
    /// Lower value = higher priority; FIFO within a level.
    Priority(Vec<u32>),
    /// Byte-accurate DRR with per-queue weights; quantum = weight × base.
    DeficitRoundRobin { weights: Vec<u32>, quantum: u64 },
}

#[derive(Debug)]
struct QueueState<T> {
    items: VecDeque<(u64, T)>, // (byte cost, payload)
    /// WRR: messages still owed this round. DRR: byte deficit.
    credit: u64,
}

/// The arbiter: N per-flow queues + a policy.
#[derive(Debug)]
pub struct Arbiter<T> {
    queues: Vec<QueueState<T>>,
    policy: Policy,
    next: usize,
    len: usize,
}

impl<T> Arbiter<T> {
    pub fn new(n_queues: usize, policy: Policy) -> Self {
        match &policy {
            Policy::WeightedRoundRobin(w) | Policy::Priority(w) => {
                assert_eq!(w.len(), n_queues, "policy weights must match queues")
            }
            Policy::DeficitRoundRobin { weights, .. } => {
                assert_eq!(weights.len(), n_queues)
            }
            Policy::RoundRobin => {}
        }
        Arbiter {
            queues: (0..n_queues)
                .map(|_| QueueState {
                    items: VecDeque::new(),
                    credit: 0,
                })
                .collect(),
            policy,
            next: 0,
            len: 0,
        }
    }

    pub fn push(&mut self, queue: usize, cost: u64, item: T) {
        self.queues[queue].items.push_back((cost, item));
        self.len += 1;
    }

    pub fn len(&self) -> usize {
        self.len
    }
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
    pub fn queue_len(&self, queue: usize) -> usize {
        self.queues[queue].items.len()
    }
    pub fn queue_bytes(&self, queue: usize) -> u64 {
        self.queues[queue].items.iter().map(|&(c, _)| c).sum()
    }

    /// Dequeue the next message per the policy: (queue, cost, item).
    pub fn pop(&mut self) -> Option<(usize, u64, T)> {
        if self.len == 0 {
            return None;
        }
        let n = self.queues.len();
        let picked = match &self.policy {
            Policy::RoundRobin => {
                let mut found = None;
                for i in 0..n {
                    let idx = (self.next + i) % n;
                    if !self.queues[idx].items.is_empty() {
                        found = Some(idx);
                        break;
                    }
                }
                let idx = found?;
                self.next = (idx + 1) % n;
                idx
            }
            Policy::WeightedRoundRobin(weights) => {
                // Serve `weight` messages from a queue before advancing.
                let weights = weights.clone();
                let mut found = None;
                for i in 0..n {
                    let idx = (self.next + i) % n;
                    if self.queues[idx].items.is_empty() {
                        continue;
                    }
                    if i > 0 {
                        // Moved past self.next: reset its round credit.
                        self.queues[idx].credit = 0;
                    }
                    found = Some(idx);
                    break;
                }
                let idx = found?;
                self.queues[idx].credit += 1;
                if self.queues[idx].credit >= weights[idx].max(1) as u64 {
                    self.queues[idx].credit = 0;
                    self.next = (idx + 1) % n;
                } else {
                    self.next = idx;
                }
                idx
            }
            Policy::Priority(prios) => {
                // Lowest priority value with a non-empty queue; RR among
                // equals via self.next.
                let best = prios
                    .iter()
                    .enumerate()
                    .filter(|&(i, _)| !self.queues[i].items.is_empty())
                    .map(|(_, &p)| p)
                    .min()?;
                let mut found = None;
                for i in 0..n {
                    let idx = (self.next + i) % n;
                    if prios[idx] == best && !self.queues[idx].items.is_empty() {
                        found = Some(idx);
                        break;
                    }
                }
                let idx = found?;
                self.next = (idx + 1) % n;
                idx
            }
            Policy::DeficitRoundRobin { weights, quantum } => {
                let weights = weights.clone();
                let quantum = *quantum;
                // Classic DRR: visit queues round-robin; top up deficit by
                // weight×quantum on each visit; serve while head fits.
                let mut idx = self.next;
                let mut guard = 0;
                loop {
                    guard += 1;
                    debug_assert!(guard < 10 * n + 100, "DRR failed to converge");
                    if self.queues[idx].items.is_empty() {
                        self.queues[idx].credit = 0; // empty queues lose deficit
                        idx = (idx + 1) % n;
                        continue;
                    }
                    let head_cost = self.queues[idx].items.front().unwrap().0;
                    if self.queues[idx].credit >= head_cost {
                        self.queues[idx].credit -= head_cost;
                        // Stay on this queue next time (serve while fits).
                        self.next = idx;
                        break idx;
                    }
                    // Not enough deficit: top up and move on.
                    self.queues[idx].credit +=
                        quantum.max(1) * weights[idx].max(1) as u64;
                    // Serve immediately if the top-up suffices; else rotate.
                    if self.queues[idx].credit >= head_cost {
                        self.queues[idx].credit -= head_cost;
                        self.next = (idx + 1) % n;
                        break idx;
                    }
                    idx = (idx + 1) % n;
                }
            }
        };
        let (cost, item) = self.queues[picked].items.pop_front()?;
        self.len -= 1;
        Some((picked, cost, item))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Helper: fill queues then measure byte share over `rounds` pops.
    fn byte_share(arb: &mut Arbiter<u32>, pops: usize, n: usize) -> Vec<u64> {
        let mut bytes = vec![0u64; n];
        for _ in 0..pops {
            if let Some((q, cost, _)) = arb.pop() {
                bytes[q] += cost;
            }
        }
        bytes
    }

    #[test]
    fn rr_fair_in_messages_not_bytes() {
        let mut arb = Arbiter::new(2, Policy::RoundRobin);
        for i in 0..1000 {
            arb.push(0, 4096, i);
            arb.push(1, 64, i);
        }
        let bytes = byte_share(&mut arb, 1000, 2);
        // Message-fair: byte ratio equals size ratio 64:1.
        let ratio = bytes[0] as f64 / bytes[1] as f64;
        assert!((ratio - 64.0).abs() < 1.0, "ratio={ratio}");
    }

    #[test]
    fn drr_fair_in_bytes() {
        let mut arb = Arbiter::new(
            2,
            Policy::DeficitRoundRobin {
                weights: vec![1, 1],
                quantum: 1500,
            },
        );
        for i in 0..4000 {
            arb.push(0, 4096, i);
            arb.push(1, 64, i);
        }
        let bytes = byte_share(&mut arb, 3000, 2);
        let ratio = bytes[0] as f64 / bytes[1] as f64;
        assert!((0.8..1.25).contains(&ratio), "byte ratio={ratio}");
    }

    #[test]
    fn drr_respects_weights() {
        let mut arb = Arbiter::new(
            2,
            Policy::DeficitRoundRobin {
                weights: vec![1, 2],
                quantum: 1500,
            },
        );
        for i in 0..6000 {
            arb.push(0, 1500, i);
            arb.push(1, 1500, i);
        }
        let bytes = byte_share(&mut arb, 6000, 2);
        let ratio = bytes[1] as f64 / bytes[0] as f64;
        assert!((1.8..2.2).contains(&ratio), "weighted ratio={ratio}");
    }

    #[test]
    fn priority_starves_low() {
        let mut arb = Arbiter::new(2, Policy::Priority(vec![0, 1]));
        for i in 0..100 {
            arb.push(0, 100, i);
            arb.push(1, 100, i);
        }
        // First 100 pops all come from queue 0.
        for _ in 0..100 {
            let (q, _, _) = arb.pop().unwrap();
            assert_eq!(q, 0);
        }
        let (q, _, _) = arb.pop().unwrap();
        assert_eq!(q, 1);
    }

    #[test]
    fn wrr_message_proportions() {
        let mut arb = Arbiter::new(2, Policy::WeightedRoundRobin(vec![3, 1]));
        for i in 0..4000 {
            arb.push(0, 100, i);
            arb.push(1, 100, i);
        }
        let mut counts = [0u32; 2];
        for _ in 0..4000 {
            let (q, _, _) = arb.pop().unwrap();
            counts[q] += 1;
        }
        let ratio = counts[0] as f64 / counts[1] as f64;
        assert!((2.7..3.3).contains(&ratio), "wrr ratio={ratio}");
    }

    #[test]
    fn empty_and_single_queue_edge_cases() {
        let mut arb: Arbiter<u32> = Arbiter::new(3, Policy::RoundRobin);
        assert!(arb.pop().is_none());
        arb.push(1, 10, 42);
        assert_eq!(arb.pop(), Some((1, 10, 42)));
        assert!(arb.pop().is_none());
        assert!(arb.is_empty());
    }

    #[test]
    fn fifo_within_queue() {
        let mut arb: Arbiter<u32> = Arbiter::new(1, Policy::RoundRobin);
        for i in 0..10 {
            arb.push(0, 1, i);
        }
        for i in 0..10 {
            assert_eq!(arb.pop().unwrap().2, i);
        }
    }

    #[test]
    fn drr_skips_empty_queues_without_hoarding() {
        let mut arb = Arbiter::new(
            3,
            Policy::DeficitRoundRobin {
                weights: vec![1, 1, 1],
                quantum: 500,
            },
        );
        // Only queue 2 has traffic; it must get full service.
        for i in 0..100 {
            arb.push(2, 1500, i);
        }
        for _ in 0..100 {
            assert_eq!(arb.pop().unwrap().0, 2);
        }
        // Now queue 0 joins; deficit hoarded while empty must not matter.
        for i in 0..10 {
            arb.push(0, 1500, i);
            arb.push(2, 1500, i);
        }
        let bytes = byte_share(&mut arb, 20, 3);
        assert_eq!(bytes[0], bytes[2]);
    }
}
