//! DMA engine scheduling: multi-queue arbitration policies.
//!
//! The paper's prototype wraps the FPGA's DMA engine with "an SR-IOV arbiter
//! (a simple round robin policy) and queues … which in our case contains
//! accelerator per-flow contexts" (§5.1). Baseline systems differ exactly
//! here: `Host_no_TS` uses weighted round-robin, PANIC uses priority +
//! weighted-fair queueing. The [`Arbiter`] is the shared mechanism; the
//! policy decides which per-flow queue supplies the next message.

pub mod arbiter;

pub use arbiter::{Arbiter, Policy};
