//! Accelerator models with communication-relevant heterogeneity (§2.2).
//!
//! The paper's "non-linearity" of accelerators has two axes, both modeled
//! here:
//!
//! 1. **Throughput vs message size** ([`curves::ThroughputCurve`]): each
//!    accelerator has a unique saturating curve — per-message setup costs
//!    make tiny messages reach a fraction of peak (Fig 3b: 64 B mixes hold
//!    an IPSec engine to 18–32% of its 32 Gbps; Fig 7a shows logarithmic,
//!    exponential, and ad-hoc curve shapes).
//! 2. **Egress/ingress ratio R** ([`Egress`]): AES keeps R=1, decompression
//!    R>1, compression R<1, SHA-3-512 has fixed 64 B output. R decides which
//!    PCIe direction an accelerator stresses and how much egress bandwidth a
//!    given SLO really needs (§5.3.1).
//!
//! [`AccelUnit`] is the simulation component: a single-server pipeline with
//! an input scheduler (pluggable [`crate::dma::Arbiter`] policy — this is
//! where PANIC's WFQ/priority vs Arcus's shaped-FIFO differ), a service time
//! drawn from the model, and an egress size from R.

pub mod curves;
pub mod unit;

pub use curves::ThroughputCurve;
pub use unit::{AccelUnit, Job, JobDone};

use crate::util::units::{Rate, Time, SECONDS};
use crate::util::Rng;

/// Egress-size behaviour (the R = Eb/Ib taxonomy of §2.2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Egress {
    /// Output bytes = ratio × input bytes (R=1 ciphers, R<1 compressors,
    /// R>1 decompressors).
    Ratio(f64),
    /// Fixed-size output regardless of input (hashes/digests).
    Fixed(u64),
}

impl Egress {
    pub fn out_bytes(self, in_bytes: u64) -> u64 {
        match self {
            Egress::Ratio(r) => ((in_bytes as f64 * r).round() as u64).max(1),
            Egress::Fixed(n) => n,
        }
    }
}

/// Jitter on the deterministic service time (§5.3.1 tests synthetic
/// accelerators under "Bi-modal, Poison [sic], and Uniform" distributions).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ServiceJitter {
    /// Deterministic pipeline (most fixed-function engines).
    None,
    /// Uniform multiplicative jitter in [1-spread, 1+spread].
    Uniform { spread: f64 },
    /// With probability `p_slow`, service takes `slow_factor`× longer
    /// (cache-miss / recompression style bimodality).
    Bimodal { p_slow: f64, slow_factor: f64 },
    /// Exponential (memoryless) service around the mean.
    Poisson,
}

impl ServiceJitter {
    fn apply(self, base: f64, rng: &mut Rng) -> f64 {
        match self {
            ServiceJitter::None => base,
            ServiceJitter::Uniform { spread } => {
                base * rng.range_f64(1.0 - spread, 1.0 + spread)
            }
            ServiceJitter::Bimodal { p_slow, slow_factor } => {
                if rng.chance(p_slow) {
                    base * slow_factor
                } else {
                    base
                }
            }
            ServiceJitter::Poisson => rng.exponential(base),
        }
    }
}

/// A parameterized accelerator model.
#[derive(Debug, Clone)]
pub struct AccelModel {
    pub name: &'static str,
    /// Peak ingress throughput at large message sizes.
    pub peak: Rate,
    /// Throughput-vs-size efficiency curve.
    pub curve: ThroughputCurve,
    /// Egress behaviour.
    pub egress: Egress,
    /// Service-time jitter.
    pub jitter: ServiceJitter,
    /// Fixed per-message pipeline latency (descriptor decode, key schedule…)
    /// added on top of the throughput-derived time.
    pub setup: Time,
}

impl AccelModel {
    /// Effective sustained ingress throughput at message size `s`.
    pub fn throughput_at(&self, msg_bytes: u64) -> Rate {
        Rate(self.peak.0 * self.curve.efficiency(msg_bytes))
    }

    /// Deterministic part of the service time for one message.
    pub fn base_service_time(&self, msg_bytes: u64) -> Time {
        let thr = self.throughput_at(msg_bytes);
        self.setup + thr.serialize_time(msg_bytes)
    }

    /// Effective sustained ingress rate at size `s` including the
    /// per-message setup cost — the rate an engine actually serves a
    /// backlogged stream of `s`-byte messages at. Capacity planning and the
    /// paper's "overall capacity" numbers are in these terms.
    pub fn effective_rate(&self, msg_bytes: u64) -> Rate {
        Rate(msg_bytes as f64 * 8.0 * SECONDS as f64 / self.base_service_time(msg_bytes) as f64)
    }

    /// Sampled service time (with jitter).
    pub fn service_time(&self, msg_bytes: u64, rng: &mut Rng) -> Time {
        let base = self.base_service_time(msg_bytes) as f64;
        self.jitter.apply(base, rng).round() as Time
    }

    /// Messages/sec the engine sustains at size `s` (derived; used by the
    /// profiler and capacity planner).
    pub fn mps_at(&self, msg_bytes: u64) -> f64 {
        SECONDS as f64 / self.base_service_time(msg_bytes) as f64
    }

    // ---- The paper's accelerator zoo -------------------------------------

    /// 32 Gbps IPSec engine (Fig 3, §3.1): strong small-message penalty
    /// (per-packet ESP header/trailer + key setup), R=1.
    pub fn ipsec_32g() -> Self {
        AccelModel {
            name: "ipsec",
            peak: Rate::gbps(34.0),
            curve: ThroughputCurve::saturating(120.0),
            egress: Egress::Ratio(1.0),
            jitter: ServiceJitter::None,
            setup: 15_000, // 15 ns per-packet ESP header/trailer + key setup
        }
    }

    /// AES-128-CBC bump-in-the-wire cipher (Fig 11a), R=1.
    pub fn aes_128() -> Self {
        AccelModel {
            name: "aes128",
            peak: Rate::gbps(42.0),
            curve: ThroughputCurve::saturating(150.0),
            egress: Egress::Ratio(1.0),
            jitter: ServiceJitter::None,
            setup: 20_000,
        }
    }

    /// SHA1-HMAC authenticator (Fig 11a): fixed 20 B digest out.
    pub fn sha1_hmac() -> Self {
        AccelModel {
            name: "sha1hmac",
            peak: Rate::gbps(26.0),
            curve: ThroughputCurve::exponential(150.0),
            egress: Egress::Fixed(20),
            jitter: ServiceJitter::None,
            setup: 40_000,
        }
    }

    /// SHA-3-512: fixed 64 B output — the §5.3.1 example of an accelerator
    /// that only ever stresses its ingress path.
    pub fn sha3_512() -> Self {
        AccelModel {
            name: "sha3_512",
            peak: Rate::gbps(21.0),
            curve: ThroughputCurve::exponential(900.0),
            egress: Egress::Fixed(64),
            jitter: ServiceJitter::None,
            setup: 50_000,
        }
    }

    /// Compression engine (RocksDB offload, Table 4): R<1 (ratio ~0.45 on
    /// mixed key-value blocks), ad-hoc curve with a block-boundary dip.
    pub fn compress() -> Self {
        AccelModel {
            name: "compress",
            peak: Rate::gbps(16.0),
            curve: ThroughputCurve::adhoc(vec![
                (64, 0.08),
                (512, 0.38),
                (4096, 0.82),
                (8192, 0.70), // dictionary reset at block boundary
                (32768, 0.95),
                (262144, 1.0),
            ]),
            egress: Egress::Ratio(0.45),
            jitter: ServiceJitter::Bimodal {
                p_slow: 0.05,
                slow_factor: 1.8, // incompressible blocks re-emitted raw
            },
            setup: 150_000,
        }
    }

    /// Decompression: R>1.
    pub fn decompress() -> Self {
        AccelModel {
            name: "decompress",
            peak: Rate::gbps(28.0),
            curve: ThroughputCurve::saturating(500.0),
            egress: Egress::Ratio(2.2),
            jitter: ServiceJitter::None,
            setup: 60_000,
        }
    }

    /// CRC32C checksum engine (RocksDB offload): tiny fixed output.
    pub fn checksum() -> Self {
        AccelModel {
            name: "checksum",
            peak: Rate::gbps(50.0),
            curve: ThroughputCurve::saturating(90.0),
            egress: Egress::Fixed(4),
            jitter: ServiceJitter::None,
            setup: 40_000,
        }
    }

    /// Synthetic accelerator with a given peak and no size penalty — used by
    /// the CaseP studies ("a synthetic 50 Gbps accelerator") to isolate
    /// communication effects from interface effects.
    pub fn synthetic(peak: Rate) -> Self {
        AccelModel {
            name: "synthetic",
            peak,
            curve: ThroughputCurve::flat(),
            egress: Egress::Ratio(1.0),
            jitter: ServiceJitter::None,
            setup: 0,
        }
    }

    /// Look up a model by config name.
    pub fn by_name(name: &str) -> Option<Self> {
        Some(match name {
            "ipsec" => Self::ipsec_32g(),
            "aes128" => Self::aes_128(),
            "sha1hmac" => Self::sha1_hmac(),
            "sha3_512" => Self::sha3_512(),
            "compress" => Self::compress(),
            "decompress" => Self::decompress(),
            "checksum" => Self::checksum(),
            _ => return None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ipsec_small_messages_crater_throughput() {
        let m = AccelModel::ipsec_32g();
        let t64 = m.effective_rate(64).as_gbps();
        let t1500 = m.effective_rate(1500).as_gbps();
        // Fig 3b: 64 B mixes deliver 18–32% of the ~32 Gbps MTU capacity.
        assert!(
            (0.18..0.32).contains(&(t64 / 32.0)),
            "64B effective {:.2} of 32G",
            t64 / 32.0
        );
        // §3.1: "overall capacity is 32 Gbps at maximum for full load,
        // MTU-sized packets".
        assert!(
            (0.90..1.05).contains(&(t1500 / 32.0)),
            "1500B effective {:.2} of 32G",
            t1500 / 32.0
        );
    }

    #[test]
    fn egress_ratios_match_taxonomy() {
        assert_eq!(AccelModel::aes_128().egress.out_bytes(1500), 1500); // R=1
        assert!(AccelModel::compress().egress.out_bytes(4096) < 4096); // R<1
        assert!(AccelModel::decompress().egress.out_bytes(4096) > 4096); // R>1
        assert_eq!(AccelModel::sha3_512().egress.out_bytes(1_000_000), 64); // fixed
        assert_eq!(AccelModel::sha3_512().egress.out_bytes(64), 64);
    }

    #[test]
    fn service_time_monotone_in_size() {
        let m = AccelModel::ipsec_32g();
        let mut prev = 0;
        for s in [64u64, 256, 512, 1500, 4096, 65536] {
            let t = m.base_service_time(s);
            assert!(t > prev, "size {s}: {t} !> {prev}");
            prev = t;
        }
    }

    #[test]
    fn synthetic_is_linear() {
        let m = AccelModel::synthetic(Rate::gbps(50.0));
        let t1 = m.base_service_time(1000);
        let t4 = m.base_service_time(4000);
        assert!(((t4 as f64 / t1 as f64) - 4.0).abs() < 0.01);
    }

    #[test]
    fn jitter_distributions_behave() {
        let mut rng = Rng::new(3);
        let base = 1_000_000.0;
        // Uniform stays within bounds.
        for _ in 0..1000 {
            let v = ServiceJitter::Uniform { spread: 0.2 }.apply(base, &mut rng);
            assert!((0.8 * base..=1.2 * base).contains(&v));
        }
        // Bimodal: slow fraction near p_slow.
        let slow = (0..10_000)
            .filter(|_| {
                ServiceJitter::Bimodal {
                    p_slow: 0.1,
                    slow_factor: 3.0,
                }
                .apply(base, &mut rng)
                    > 2.0 * base
            })
            .count();
        assert!((800..1200).contains(&slow), "slow={slow}");
        // Poisson: mean close to base.
        let n = 20_000;
        let sum: f64 = (0..n)
            .map(|_| ServiceJitter::Poisson.apply(base, &mut rng))
            .sum();
        assert!((sum / n as f64 - base).abs() / base < 0.05);
    }

    #[test]
    fn mps_inverse_of_service_time() {
        let m = AccelModel::aes_128();
        let mps = m.mps_at(1500);
        let t = m.base_service_time(1500);
        assert!((mps * t as f64 / SECONDS as f64 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn by_name_roundtrip() {
        for name in [
            "ipsec",
            "aes128",
            "sha1hmac",
            "sha3_512",
            "compress",
            "decompress",
            "checksum",
        ] {
            assert_eq!(AccelModel::by_name(name).unwrap().name, name);
        }
        assert!(AccelModel::by_name("nope").is_none());
    }
}
