//! The accelerator pipeline unit: input arbiter + single-server engine.
//!
//! Models the accelerator interface the paper studies: per-flow input queues
//! feed a single processing pipeline through a scheduling policy. Under
//! PANIC this policy is priority/WFQ (reactive); under Arcus the queues are
//! *already shaped* upstream so a plain FIFO/RR suffices — the difference in
//! outcomes is the content of Fig 3 vs Fig 8.
//!
//! DES integration follows the link/fabric pattern: `submit` enqueues,
//! `pump(now)` advances the engine and returns completed jobs plus the next
//! wake time.

use super::AccelModel;
use crate::dma::{Arbiter, Policy};
use crate::util::units::Time;
use crate::util::Rng;

/// One accelerator invocation travelling through the unit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Job {
    /// Opaque id the wiring uses to correlate completions.
    pub id: u64,
    /// Flow (input queue) index.
    pub flow: usize,
    /// Ingress payload bytes.
    pub bytes: u64,
}

/// A finished invocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JobDone {
    pub job: Job,
    /// Completion time.
    pub at: Time,
    /// Egress payload bytes (from the model's R).
    pub egress_bytes: u64,
}

/// Single-engine accelerator with per-flow input queues.
#[derive(Debug)]
pub struct AccelUnit {
    model: AccelModel,
    input: Arbiter<Job>,
    /// Job in the pipeline and its finish time.
    current: Option<(Job, Time)>,
    rng: Rng,
    /// Busy-time accounting for utilization reports.
    busy: Time,
    served_bytes: u64,
    /// Fault-injection throughput multiplier in (0, 1]; 1.0 = healthy.
    /// Service times stretch by `1/slowdown` while degraded — the job in
    /// the pipeline keeps its finish time (a fault never rewrites the
    /// past), only newly started jobs pay the penalty.
    slowdown: f64,
}

impl AccelUnit {
    pub fn new(model: AccelModel, n_flows: usize, policy: Policy, seed: u64) -> Self {
        AccelUnit {
            model,
            input: Arbiter::new(n_flows, policy),
            current: None,
            rng: Rng::for_stream(seed, 0xACCE1),
            busy: 0,
            served_bytes: 0,
            slowdown: 1.0,
        }
    }

    /// Fault injection: scale sustained throughput by `factor` ∈ (0, 1]
    /// (1.0 restores full health). See [`crate::faults`].
    pub fn set_slowdown(&mut self, factor: f64) {
        debug_assert!(factor > 0.0 && factor <= 1.0, "slowdown factor {factor}");
        self.slowdown = factor.clamp(f64::MIN_POSITIVE, 1.0);
    }

    /// Current fault-injection throughput multiplier (1.0 = healthy).
    pub fn slowdown(&self) -> f64 {
        self.slowdown
    }

    /// Service time for one job under the current degradation.
    fn job_time(&mut self, bytes: u64) -> Time {
        let t = self.model.service_time(bytes, &mut self.rng);
        if self.slowdown < 1.0 {
            (t as f64 / self.slowdown).round() as Time
        } else {
            t
        }
    }

    pub fn model(&self) -> &AccelModel {
        &self.model
    }

    /// Queue an invocation (payload already DMA'd to the engine).
    pub fn submit(&mut self, job: Job) {
        self.input.push(job.flow, job.bytes, job);
    }

    /// Number of queued (not yet started) jobs.
    pub fn backlog(&self) -> usize {
        self.input.len()
    }

    /// Queued bytes for one flow (backpressure signal, step 6 in Fig 4).
    pub fn flow_backlog_bytes(&self, flow: usize) -> u64 {
        self.input.queue_bytes(flow)
    }

    /// Advance to `now`; complete due jobs, start queued ones.
    ///
    /// Allocates a fresh `Vec` per call; the simulation hot path uses
    /// [`Self::pump_into`] with a reused buffer instead.
    pub fn pump(&mut self, now: Time) -> (Vec<JobDone>, Option<Time>) {
        let mut done = Vec::new();
        let next = self.pump_into(now, &mut done);
        (done, next)
    }

    /// Allocation-free pump: appends completed jobs to `done` (which the
    /// caller reuses across calls) and returns the next wake time.
    pub fn pump_into(&mut self, now: Time, done: &mut Vec<JobDone>) -> Option<Time> {
        loop {
            match self.current {
                Some((job, fin)) if fin <= now => {
                    self.current = None;
                    self.served_bytes += job.bytes;
                    done.push(JobDone {
                        job,
                        at: fin,
                        egress_bytes: self.model.egress.out_bytes(job.bytes),
                    });
                    // Start the next job back-to-back at `fin`.
                    if let Some((_, _, next)) = self.input.pop() {
                        let t = self.job_time(next.bytes);
                        self.busy += t;
                        self.current = Some((next, fin + t));
                    }
                }
                Some((_, fin)) => return Some(fin),
                None => match self.input.pop() {
                    Some((_, _, job)) => {
                        let t = self.job_time(job.bytes);
                        self.busy += t;
                        self.current = Some((job, now + t));
                    }
                    None => return None,
                },
            }
        }
    }

    /// Fraction of `elapsed` the engine spent busy.
    pub fn utilization(&self, elapsed: Time) -> f64 {
        if elapsed == 0 {
            0.0
        } else {
            self.busy as f64 / elapsed as f64
        }
    }

    pub fn served_bytes(&self) -> u64 {
        self.served_bytes
    }

    pub fn idle(&self) -> bool {
        self.current.is_none() && self.input.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::{Rate, SECONDS};

    fn drain(unit: &mut AccelUnit) -> Vec<JobDone> {
        let mut out = Vec::new();
        let mut now = 0;
        loop {
            let (done, next) = unit.pump(now);
            out.extend(done);
            match next {
                Some(t) => now = t,
                None => break,
            }
        }
        out
    }

    #[test]
    fn throughput_matches_model_at_size() {
        let model = AccelModel::ipsec_32g();
        // Expected sustained rate includes the per-message setup cost.
        let expect =
            Rate(1500.0 * 8.0 * SECONDS as f64 / model.base_service_time(1500) as f64);
        let mut unit = AccelUnit::new(model, 1, Policy::RoundRobin, 1);
        let n = 5000u64;
        for i in 0..n {
            unit.submit(Job {
                id: i,
                flow: 0,
                bytes: 1500,
            });
        }
        let done = drain(&mut unit);
        let last = done.last().unwrap().at;
        let rate = (n * 1500) as f64 * 8.0 * SECONDS as f64 / last as f64;
        assert!(
            (rate / expect.as_bits_per_sec()) > 0.98,
            "rate={:.2}G expect={:.2}G",
            rate / 1e9,
            expect.as_gbps()
        );
    }

    #[test]
    fn mixed_sizes_drag_shared_throughput() {
        // The Fig 3b effect: a 64 B flow mixed into a 1500 B flow drags the
        // engine's aggregate bandwidth far below peak.
        let model = AccelModel::ipsec_32g();
        let mtu_rate = model.effective_rate(1500).as_gbps();
        let mut unit = AccelUnit::new(model, 2, Policy::RoundRobin, 1);
        // VM2 floods 64 B messages at 7× VM1's 1500 B message rate (the
        // CaseT1 high-load points).
        let n = 8000u64;
        let mut bytes = 0;
        for i in 0..n {
            let size = if i % 8 == 0 { 1500 } else { 64 };
            bytes += size;
            unit.submit(Job {
                id: i,
                flow: (i % 2) as usize,
                bytes: size,
            });
        }
        let done = drain(&mut unit);
        let last = done.last().unwrap().at;
        let agg = bytes as f64 * 8.0 * SECONDS as f64 / last as f64 / 1e9;
        assert!(
            agg < 0.65 * mtu_rate,
            "aggregate {agg:.1} Gbps should be well under the {mtu_rate:.1} Gbps MTU rate"
        );
    }

    #[test]
    fn egress_sizes_follow_model() {
        let mut unit = AccelUnit::new(AccelModel::compress(), 1, Policy::RoundRobin, 2);
        unit.submit(Job {
            id: 0,
            flow: 0,
            bytes: 4096,
        });
        let done = drain(&mut unit);
        assert_eq!(done[0].egress_bytes, (4096.0f64 * 0.45).round() as u64);
    }

    #[test]
    fn work_conserving_no_idle_gaps() {
        let model = AccelModel::synthetic(Rate::gbps(10.0));
        let per_job = model.base_service_time(1000);
        let mut unit = AccelUnit::new(model, 1, Policy::RoundRobin, 3);
        for i in 0..100 {
            unit.submit(Job {
                id: i,
                flow: 0,
                bytes: 1000,
            });
        }
        let done = drain(&mut unit);
        assert_eq!(done.last().unwrap().at, 100 * per_job);
    }

    #[test]
    fn slowdown_stretches_service_and_restores() {
        let model = AccelModel::synthetic(Rate::gbps(10.0));
        let per_job = model.base_service_time(1000);
        let mut unit = AccelUnit::new(model, 1, Policy::RoundRobin, 3);
        unit.set_slowdown(0.5); // half throughput = double service time
        for i in 0..10 {
            unit.submit(Job { id: i, flow: 0, bytes: 1000 });
        }
        let done = drain(&mut unit);
        assert_eq!(done.last().unwrap().at, 10 * 2 * per_job);
        // Healing restores the model's native rate for new jobs.
        unit.set_slowdown(1.0);
        for i in 10..20 {
            unit.submit(Job { id: i, flow: 0, bytes: 1000 });
        }
        let healed = drain(&mut unit);
        let span = healed.last().unwrap().at - healed.first().unwrap().at;
        assert_eq!(span, 9 * per_job);
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut unit = AccelUnit::new(AccelModel::compress(), 1, Policy::RoundRobin, 7);
            for i in 0..200 {
                unit.submit(Job {
                    id: i,
                    flow: 0,
                    bytes: 4096,
                });
            }
            drain(&mut unit)
                .into_iter()
                .map(|d| d.at)
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}
