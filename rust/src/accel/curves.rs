//! Throughput-vs-message-size efficiency curves (Fig 7a).
//!
//! The paper identifies three representative shapes: logarithmic-saturating,
//! exponential-saturating, and "uniquely ad-hoc" piecewise curves. All map a
//! message size to an efficiency in (0, 1] that multiplies the engine's peak
//! throughput.

/// Efficiency curve: fraction of peak throughput sustained at a given size.
#[derive(Debug, Clone, PartialEq)]
pub enum ThroughputCurve {
    /// Always 1.0 (synthetic linear accelerator).
    Flat,
    /// Michaelis–Menten saturating: eff(s) = s / (s + k). Logarithmic-ish
    /// rise; `k` is the size at 50% efficiency.
    Saturating { k: f64 },
    /// Exponential saturating: eff(s) = 1 - exp(-s/tau).
    Exponential { tau: f64 },
    /// Piecewise-linear over (size, efficiency) control points — the
    /// "uniquely ad-hoc" curves with local dips (e.g. block-boundary
    /// effects in compressors).
    AdHoc { points: Vec<(u64, f64)> },
}

impl ThroughputCurve {
    pub fn flat() -> Self {
        ThroughputCurve::Flat
    }
    pub fn saturating(k: f64) -> Self {
        assert!(k > 0.0);
        ThroughputCurve::Saturating { k }
    }
    pub fn exponential(tau: f64) -> Self {
        assert!(tau > 0.0);
        ThroughputCurve::Exponential { tau }
    }
    /// Points must be sorted by size and have efficiencies in (0, 1].
    pub fn adhoc(points: Vec<(u64, f64)>) -> Self {
        assert!(!points.is_empty());
        assert!(points.windows(2).all(|w| w[0].0 < w[1].0), "sizes sorted");
        assert!(points.iter().all(|&(_, e)| e > 0.0 && e <= 1.0));
        ThroughputCurve::AdHoc { points }
    }

    /// Efficiency at message size `s` (bytes).
    pub fn efficiency(&self, s: u64) -> f64 {
        let s = s.max(1);
        match self {
            ThroughputCurve::Flat => 1.0,
            ThroughputCurve::Saturating { k } => {
                let x = s as f64;
                x / (x + k)
            }
            ThroughputCurve::Exponential { tau } => 1.0 - (-(s as f64) / tau).exp(),
            ThroughputCurve::AdHoc { points } => {
                let x = s;
                if x <= points[0].0 {
                    // Scale below the first point towards zero smoothly.
                    return points[0].1 * x as f64 / points[0].0 as f64;
                }
                if x >= points[points.len() - 1].0 {
                    return points[points.len() - 1].1;
                }
                let i = points.partition_point(|&(px, _)| px <= x) - 1;
                let (x0, y0) = points[i];
                let (x1, y1) = points[i + 1];
                let t = (x - x0) as f64 / (x1 - x0) as f64;
                y0 + t * (y1 - y0)
            }
        }
    }

    /// Sample the curve at standard sizes (for Fig 7a reports).
    pub fn sample(&self, sizes: &[u64]) -> Vec<(u64, f64)> {
        sizes.iter().map(|&s| (s, self.efficiency(s))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_is_one_everywhere() {
        let c = ThroughputCurve::flat();
        for s in [1u64, 64, 1500, 1 << 20] {
            assert_eq!(c.efficiency(s), 1.0);
        }
    }

    #[test]
    fn saturating_half_at_k() {
        let c = ThroughputCurve::saturating(512.0);
        assert!((c.efficiency(512) - 0.5).abs() < 1e-9);
        assert!(c.efficiency(64) < 0.2);
        assert!(c.efficiency(65536) > 0.99);
    }

    #[test]
    fn exponential_63pct_at_tau() {
        let c = ThroughputCurve::exponential(1000.0);
        assert!((c.efficiency(1000) - 0.632).abs() < 0.01);
    }

    #[test]
    fn curves_monotone_except_adhoc() {
        for c in [
            ThroughputCurve::saturating(300.0),
            ThroughputCurve::exponential(700.0),
        ] {
            let mut prev = 0.0;
            for s in (6..20).map(|e| 1u64 << e) {
                let e = c.efficiency(s);
                assert!(e >= prev);
                prev = e;
            }
        }
    }

    #[test]
    fn adhoc_interpolates_and_dips() {
        let c = ThroughputCurve::adhoc(vec![(100, 0.2), (1000, 0.9), (2000, 0.5)]);
        assert!((c.efficiency(100) - 0.2).abs() < 1e-9);
        assert!((c.efficiency(550) - 0.55).abs() < 1e-9); // midpoint interp
        assert!((c.efficiency(1000) - 0.9).abs() < 1e-9);
        assert!(c.efficiency(1500) < 0.9); // the dip
        assert!((c.efficiency(5000) - 0.5).abs() < 1e-9); // clamps right
        assert!(c.efficiency(50) < 0.2); // scales toward zero left
    }

    #[test]
    #[should_panic]
    fn adhoc_rejects_unsorted() {
        let _ = ThroughputCurve::adhoc(vec![(1000, 0.5), (100, 0.2)]);
    }

    #[test]
    fn efficiency_never_zero_or_above_one() {
        let curves = [
            ThroughputCurve::flat(),
            ThroughputCurve::saturating(400.0),
            ThroughputCurve::exponential(900.0),
            ThroughputCurve::adhoc(vec![(64, 0.1), (4096, 1.0)]),
        ];
        for c in &curves {
            for s in [1u64, 63, 64, 65, 1499, 1500, 1 << 22] {
                let e = c.efficiency(s);
                assert!(e > 0.0 && e <= 1.0, "{c:?} at {s}: {e}");
            }
        }
    }
}
