//! Offline shim for the `anyhow` crate.
//!
//! The build environment has no registry access, so this crate reimplements
//! the small slice of anyhow's API the workspace uses, on std alone:
//!
//! - [`Error`]: an erased error with a human-readable cause chain,
//!   constructed from any `std::error::Error` (capturing its `source()`
//!   chain) or from a message. `{}` prints the outermost message, `{:#}`
//!   prints the full chain joined by `": "` — the same formatting contract
//!   as real anyhow.
//! - [`Result`] with a defaulted error parameter.
//! - [`Context`]: `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! - The [`anyhow!`], [`bail!`] and [`ensure!`] macros.
//!
//! Dropping in the real crate requires no source changes.

use std::fmt;

/// An erased error: an outermost message plus the chain of causes.
pub struct Error {
    /// `chain[0]` is the outermost message; later entries are causes.
    chain: Vec<String>,
}

impl Error {
    /// Construct from a displayable message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message (used by [`Context`]).
    pub fn context(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

// The blanket From mirrors anyhow's: it is the hook `?` uses. `Error`
// itself deliberately does NOT implement `std::error::Error`, which is what
// keeps this impl coherent next to `impl From<T> for T`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

/// `anyhow::Result<T>` — error parameter defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Add context to errors (on `Result`) or turn `None` into an error.
pub trait Context<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "missing thing");
    }

    #[test]
    fn context_chains_and_alternate_formats() {
        let e: Result<()> = Err(io_err());
        let e = e.with_context(|| "loading config").unwrap_err();
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: missing thing");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing here").unwrap_err();
        assert_eq!(e.to_string(), "nothing here");
        assert_eq!(Some(5).context("unused").unwrap(), 5);
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("x = {}", 42);
        assert_eq!(e.to_string(), "x = 42");
        fn bails(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            bail!("always")
        }
        assert_eq!(bails(false).unwrap_err().to_string(), "flag was false");
        assert_eq!(bails(true).unwrap_err().to_string(), "always");
    }

    #[test]
    fn context_on_anyhow_result_rewraps() {
        let e: Result<()> = Err(Error::msg("inner"));
        let e = e.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert_eq!(e.chain().count(), 2);
    }
}
