//! Offline shim for the `flate2` crate.
//!
//! Only the `write::DeflateEncoder` / `write::DeflateDecoder` pair the
//! workspace uses is provided. The wire format is NOT RFC 1951 deflate — it
//! is a self-contained LZSS container (length header + flag-byte token
//! stream with 12-bit offsets / 4-bit lengths over a 4 KB window), which
//! gives real LZ77-style compression on repetitive payloads and exact
//! round-trips on arbitrary data. Both directions use this codec, so blocks
//! written by the encoder are always readable by the decoder; no external
//! system consumes the bytes.
//!
//! Dropping in the real crate requires no source changes (and upgrades the
//! format to actual deflate).

use std::io::{self, Write};

/// Compression level. Accepted for API compatibility; the LZSS codec has a
/// single effort level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Compression(pub u32);

impl Compression {
    pub fn new(level: u32) -> Compression {
        Compression(level)
    }
    pub fn none() -> Compression {
        Compression(0)
    }
    pub fn fast() -> Compression {
        Compression(1)
    }
    pub fn best() -> Compression {
        Compression(9)
    }
}

impl Default for Compression {
    fn default() -> Compression {
        Compression(6)
    }
}

const WINDOW: usize = 4096; // offsets 1..=4095 (12 bits)
const MIN_MATCH: usize = 3;
const MAX_MATCH: usize = 18; // 4-bit length field stores len - 3
const HASH_BITS: u32 = 13;
const MAX_CHAIN: usize = 32;

#[inline]
fn hash3(a: u8, b: u8, c: u8) -> usize {
    let v = u32::from_le_bytes([a, b, c, 0]);
    (v.wrapping_mul(0x9E37_79B1) >> (32 - HASH_BITS)) as usize
}

/// Compress `input` into the LZSS container format.
pub fn lzss_compress(input: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(input.len() / 2 + 16);
    out.extend_from_slice(&(input.len() as u64).to_le_bytes());

    let mut head = vec![usize::MAX; 1 << HASH_BITS];
    let mut prev = vec![usize::MAX; input.len()];
    let insert = |pos: usize, head: &mut [usize], prev: &mut [usize]| {
        if pos + MIN_MATCH <= input.len() {
            let h = hash3(input[pos], input[pos + 1], input[pos + 2]);
            prev[pos] = head[h];
            head[h] = pos;
        }
    };

    let mut flag = 0u8;
    let mut nflag = 0u32;
    let mut flag_idx = out.len();
    out.push(0);

    let mut i = 0;
    while i < input.len() {
        // Greedy best match against the hash chain.
        let mut best_len = 0usize;
        let mut best_off = 0usize;
        if i + MIN_MATCH <= input.len() {
            let h = hash3(input[i], input[i + 1], input[i + 2]);
            let mut cand = head[h];
            let mut steps = 0;
            while cand != usize::MAX && i - cand < WINDOW && steps < MAX_CHAIN {
                let limit = MAX_MATCH.min(input.len() - i);
                let mut l = 0;
                // `cand + l` may run past `i` (overlapping match): the
                // decoder copies byte-by-byte, so the comparison against
                // `input[cand + l]` is exactly what it will reproduce.
                while l < limit && input[cand + l] == input[i + l] {
                    l += 1;
                }
                if l > best_len {
                    best_len = l;
                    best_off = i - cand;
                    if l == MAX_MATCH {
                        break;
                    }
                }
                steps += 1;
                cand = prev[cand];
            }
        }

        if best_len >= MIN_MATCH {
            flag |= 1 << nflag;
            out.push((best_off & 0xFF) as u8);
            out.push((((best_off >> 8) as u8) << 4) | ((best_len - MIN_MATCH) as u8));
            for j in i..i + best_len {
                insert(j, &mut head, &mut prev);
            }
            i += best_len;
        } else {
            out.push(input[i]);
            insert(i, &mut head, &mut prev);
            i += 1;
        }

        nflag += 1;
        if nflag == 8 {
            out[flag_idx] = flag;
            flag = 0;
            nflag = 0;
            flag_idx = out.len();
            out.push(0);
        }
    }

    if nflag > 0 {
        out[flag_idx] = flag;
    } else {
        // Trailing placeholder flag byte was never used.
        debug_assert_eq!(flag_idx, out.len() - 1);
        out.pop();
    }
    out
}

fn corrupt(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("lzss: {msg}"))
}

/// Decompress an LZSS container produced by [`lzss_compress`].
pub fn lzss_decompress(data: &[u8]) -> io::Result<Vec<u8>> {
    if data.len() < 8 {
        return Err(corrupt("truncated header"));
    }
    let n = u64::from_le_bytes(data[..8].try_into().unwrap()) as usize;
    let mut out = Vec::with_capacity(n);
    let mut i = 8;
    let mut flag = 0u8;
    let mut nflag = 8u32;
    while out.len() < n {
        if nflag == 8 {
            flag = *data.get(i).ok_or_else(|| corrupt("missing flag byte"))?;
            i += 1;
            nflag = 0;
        }
        let is_match = (flag >> nflag) & 1 == 1;
        nflag += 1;
        if is_match {
            let b0 = *data.get(i).ok_or_else(|| corrupt("truncated match"))?;
            let b1 = *data.get(i + 1).ok_or_else(|| corrupt("truncated match"))?;
            i += 2;
            let off = (((b1 >> 4) as usize) << 8) | b0 as usize;
            let len = (b1 & 0x0F) as usize + MIN_MATCH;
            if off == 0 || off > out.len() {
                return Err(corrupt("bad match offset"));
            }
            for _ in 0..len {
                let b = out[out.len() - off];
                out.push(b);
            }
        } else {
            out.push(*data.get(i).ok_or_else(|| corrupt("truncated literal"))?);
            i += 1;
        }
    }
    if out.len() != n {
        return Err(corrupt("length mismatch"));
    }
    Ok(out)
}

pub mod write {
    //! Write-side adapters matching `flate2::write`.

    use super::{lzss_compress, lzss_decompress, Compression};
    use std::io::{self, Write};

    /// Buffers writes; compresses and forwards to the inner writer on
    /// [`DeflateEncoder::finish`].
    pub struct DeflateEncoder<W: Write> {
        inner: W,
        buf: Vec<u8>,
    }

    impl<W: Write> DeflateEncoder<W> {
        pub fn new(inner: W, _level: Compression) -> Self {
            DeflateEncoder { inner, buf: Vec::new() }
        }

        pub fn finish(mut self) -> io::Result<W> {
            let packed = lzss_compress(&self.buf);
            self.inner.write_all(&packed)?;
            self.inner.flush()?;
            Ok(self.inner)
        }
    }

    impl<W: Write> Write for DeflateEncoder<W> {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            self.buf.extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    /// Buffers writes; decompresses and forwards to the inner writer on
    /// [`DeflateDecoder::finish`].
    pub struct DeflateDecoder<W: Write> {
        inner: W,
        buf: Vec<u8>,
    }

    impl<W: Write> DeflateDecoder<W> {
        pub fn new(inner: W) -> Self {
            DeflateDecoder { inner, buf: Vec::new() }
        }

        pub fn finish(mut self) -> io::Result<W> {
            let plain = lzss_decompress(&self.buf)?;
            self.inner.write_all(&plain)?;
            self.inner.flush()?;
            Ok(self.inner)
        }
    }

    impl<W: Write> Write for DeflateDecoder<W> {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            self.buf.extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::write::{DeflateDecoder, DeflateEncoder};
    use super::*;

    fn roundtrip(data: &[u8]) -> Vec<u8> {
        let mut enc = DeflateEncoder::new(Vec::new(), Compression::fast());
        enc.write_all(data).unwrap();
        let packed = enc.finish().unwrap();
        let mut dec = DeflateDecoder::new(Vec::new());
        dec.write_all(&packed).unwrap();
        dec.finish().unwrap()
    }

    #[test]
    fn roundtrip_empty_and_tiny() {
        assert_eq!(roundtrip(b""), b"");
        assert_eq!(roundtrip(b"a"), b"a");
        assert_eq!(roundtrip(b"ab"), b"ab");
        assert_eq!(roundtrip(b"abc"), b"abc");
    }

    #[test]
    fn roundtrip_arbitrary_bytes() {
        // Deterministic pseudo-random payload (incompressible-ish).
        let mut x = 0x12345678u32;
        let data: Vec<u8> = (0..50_000)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 17;
                x ^= x << 5;
                x as u8
            })
            .collect();
        assert_eq!(roundtrip(&data), data);
    }

    #[test]
    fn periodic_data_compresses() {
        // Period-251 pattern: needs real back-references, not RLE.
        let data: Vec<u8> = (0..10_000u32).map(|i| (i % 251) as u8).collect();
        let packed = lzss_compress(&data);
        assert!(packed.len() < data.len() / 2, "packed {}", packed.len());
        assert_eq!(lzss_decompress(&packed).unwrap(), data);
    }

    #[test]
    fn constant_data_compresses_via_overlap() {
        let data = vec![0x42u8; 4096];
        let packed = lzss_compress(&data);
        assert!(packed.len() < 700, "packed {}", packed.len());
        assert_eq!(lzss_decompress(&packed).unwrap(), data);
    }

    #[test]
    fn corrupt_input_errors() {
        assert!(lzss_decompress(&[1, 2, 3]).is_err());
        // Valid header claiming bytes that aren't there.
        let mut bad = (100u64).to_le_bytes().to_vec();
        bad.push(0); // flag byte: 8 literals promised, none present
        assert!(lzss_decompress(&bad).is_err());
    }
}
