//! Offline shim for the `sha2` crate: a straightforward pure-Rust SHA-256
//! exposing the one-shot `Sha256::digest` the workspace uses. The round
//! constants are derived at first use from the fractional parts of the cube
//! roots of the first 64 primes (the FIPS 180-4 definition), so there is no
//! 64-entry hex table to mistype.

/// Marker trait so `use sha2::Digest;` keeps compiling; `digest` itself is
/// an inherent associated function on [`Sha256`].
pub trait Digest {}

pub struct Sha256;

impl Digest for Sha256 {}

const H0: [u32; 8] = [
    0x6A09_E667,
    0xBB67_AE85,
    0x3C6E_F372,
    0xA54F_F53A,
    0x510E_527F,
    0x9B05_688C,
    0x1F83_D9AB,
    0x5BE0_CD19,
];

fn first_primes<const N: usize>() -> [u64; N] {
    let mut out = [0u64; N];
    let mut found = 0;
    let mut candidate = 2u64;
    while found < N {
        let mut is_prime = true;
        let mut d = 2;
        while d * d <= candidate {
            if candidate % d == 0 {
                is_prime = false;
                break;
            }
            d += 1;
        }
        if is_prime {
            out[found] = candidate;
            found += 1;
        }
        candidate += 1;
    }
    out
}

/// Largest x with x³ ≤ n (binary search; exact, no floating point).
fn icbrt(n: u128) -> u128 {
    let mut lo = 0u128;
    let mut hi = 1u128 << 36; // (2^36)^3 = 2^108 > any input we use
    while lo + 1 < hi {
        let mid = (lo + hi) / 2;
        if mid.checked_mul(mid).and_then(|m| m.checked_mul(mid)).map(|c| c <= n).unwrap_or(false) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

/// K[i] = first 32 fractional bits of cbrt(prime_i), per FIPS 180-4.
/// floor(cbrt(p · 2^96)) = floor(cbrt(p) · 2^32); its low 32 bits are the
/// fractional bits, computed exactly in integers.
fn round_constants() -> [u32; 64] {
    let primes = first_primes::<64>();
    let mut k = [0u32; 64];
    for (i, &p) in primes.iter().enumerate() {
        k[i] = (icbrt((p as u128) << 96) & 0xFFFF_FFFF) as u32;
    }
    k
}

/// Round constants derived once per process — `digest` in a hot loop pays
/// only the hashing cost (this backs the table5 CPU-baseline measurement).
fn k() -> &'static [u32; 64] {
    static K: std::sync::OnceLock<[u32; 64]> = std::sync::OnceLock::new();
    K.get_or_init(round_constants)
}

impl Sha256 {
    /// One-shot SHA-256 digest.
    pub fn digest(data: impl AsRef<[u8]>) -> [u8; 32] {
        let data = data.as_ref();
        let k = k();
        let mut h = H0;

        // Padded message: data || 0x80 || zeros || 64-bit bit length.
        let bit_len = (data.len() as u64).wrapping_mul(8);
        let mut msg = data.to_vec();
        msg.push(0x80);
        while msg.len() % 64 != 56 {
            msg.push(0);
        }
        msg.extend_from_slice(&bit_len.to_be_bytes());

        for block in msg.chunks_exact(64) {
            let mut w = [0u32; 64];
            for (i, word) in block.chunks_exact(4).enumerate() {
                w[i] = u32::from_be_bytes([word[0], word[1], word[2], word[3]]);
            }
            for i in 16..64 {
                let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
                let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
                w[i] = w[i - 16]
                    .wrapping_add(s0)
                    .wrapping_add(w[i - 7])
                    .wrapping_add(s1);
            }

            let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut hh] = h;
            for i in 0..64 {
                let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
                let ch = (e & f) ^ (!e & g);
                let t1 = hh
                    .wrapping_add(s1)
                    .wrapping_add(ch)
                    .wrapping_add(k[i])
                    .wrapping_add(w[i]);
                let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
                let maj = (a & b) ^ (a & c) ^ (b & c);
                let t2 = s0.wrapping_add(maj);
                hh = g;
                g = f;
                f = e;
                e = d.wrapping_add(t1);
                d = c;
                c = b;
                b = a;
                a = t1.wrapping_add(t2);
            }
            h[0] = h[0].wrapping_add(a);
            h[1] = h[1].wrapping_add(b);
            h[2] = h[2].wrapping_add(c);
            h[3] = h[3].wrapping_add(d);
            h[4] = h[4].wrapping_add(e);
            h[5] = h[5].wrapping_add(f);
            h[6] = h[6].wrapping_add(g);
            h[7] = h[7].wrapping_add(hh);
        }

        let mut out = [0u8; 32];
        for (i, word) in h.iter().enumerate() {
            out[i * 4..(i + 1) * 4].copy_from_slice(&word.to_be_bytes());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(d: &[u8; 32]) -> String {
        d.iter().map(|b| format!("{b:02x}")).collect()
    }

    #[test]
    fn fips_vectors() {
        assert_eq!(
            hex(&Sha256::digest(b"abc")),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            hex(&Sha256::digest(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
    }

    #[test]
    fn multi_block_message() {
        // 3 blocks' worth of data exercises the chunk loop.
        let data = vec![0x61u8; 150];
        let d1 = Sha256::digest(&data);
        let mut data2 = data.clone();
        data2[149] = 0x62;
        assert_ne!(d1, Sha256::digest(&data2));
    }
}
