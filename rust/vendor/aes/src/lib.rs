//! Offline shim for the `aes` crate: pure-Rust AES-128 block encryption
//! behind the `cipher` trait surface the workspace uses
//! (`KeyInit::new`, `BlockEncrypt::encrypt_block`, `GenericArray`).
//!
//! The S-box is generated at key-setup time from its FIPS-197 definition
//! (multiplicative inverse in GF(2^8) followed by the affine transform), so
//! there is no 256-entry table to mistype.

pub mod cipher {
    //! Subset of the `cipher` crate's surface.

    pub mod generic_array {
        /// 16-byte block, layout-compatible with `[u8; 16]`.
        #[repr(transparent)]
        #[derive(Debug, Clone, Copy, PartialEq, Eq)]
        pub struct GenericArray(pub [u8; 16]);

        impl GenericArray {
            /// View a 16-byte slice as a block (panics on wrong length,
            /// like the real crate).
            pub fn from_slice(slice: &[u8]) -> &GenericArray {
                assert_eq!(slice.len(), 16, "GenericArray::from_slice needs 16 bytes");
                // SAFETY: repr(transparent) over [u8; 16]; length checked;
                // alignment of both types is 1.
                unsafe { &*(slice.as_ptr() as *const GenericArray) }
            }

            pub fn as_slice(&self) -> &[u8] {
                &self.0
            }
        }
    }

    use generic_array::GenericArray;

    /// Construct a cipher from a key block.
    pub trait KeyInit: Sized {
        fn new(key: &GenericArray) -> Self;
    }

    /// Encrypt one 16-byte block in place.
    pub trait BlockEncrypt {
        fn encrypt_block(&self, block: &mut GenericArray);
    }
}

use cipher::generic_array::GenericArray;
use cipher::{BlockEncrypt, KeyInit};

/// GF(2^8) multiply modulo x^8 + x^4 + x^3 + x + 1.
fn gmul(mut a: u8, mut b: u8) -> u8 {
    let mut p = 0u8;
    for _ in 0..8 {
        if b & 1 != 0 {
            p ^= a;
        }
        let hi = a & 0x80;
        a <<= 1;
        if hi != 0 {
            a ^= 0x1B;
        }
        b >>= 1;
    }
    p
}

/// FIPS-197 S-box: inverse in GF(2^8) (x^254) then the affine transform.
fn build_sbox() -> [u8; 256] {
    let mut sbox = [0u8; 256];
    for (i, slot) in sbox.iter_mut().enumerate() {
        let x = i as u8;
        let inv = if x == 0 {
            0
        } else {
            // x^254 = x^(2+4+8+16+32+64+128) via square-and-multiply.
            let mut acc = 1u8;
            let mut sq = x;
            for _ in 1..8 {
                sq = gmul(sq, sq);
                acc = gmul(acc, sq);
            }
            acc
        };
        *slot = inv
            ^ inv.rotate_left(1)
            ^ inv.rotate_left(2)
            ^ inv.rotate_left(3)
            ^ inv.rotate_left(4)
            ^ 0x63;
    }
    sbox
}

/// AES-128 with precomputed round keys.
pub struct Aes128 {
    sbox: [u8; 256],
    round_keys: [[u8; 16]; 11],
}

impl KeyInit for Aes128 {
    fn new(key: &GenericArray) -> Self {
        let sbox = build_sbox();
        // Key expansion over 44 words.
        let mut w = [[0u8; 4]; 44];
        for i in 0..4 {
            w[i].copy_from_slice(&key.0[i * 4..(i + 1) * 4]);
        }
        let mut rcon = 1u8;
        for i in 4..44 {
            let mut t = w[i - 1];
            if i % 4 == 0 {
                t.rotate_left(1); // RotWord
                for b in t.iter_mut() {
                    *b = sbox[*b as usize]; // SubWord
                }
                t[0] ^= rcon;
                rcon = gmul(rcon, 2);
            }
            for j in 0..4 {
                w[i][j] = w[i - 4][j] ^ t[j];
            }
        }
        let mut round_keys = [[0u8; 16]; 11];
        for r in 0..11 {
            for c in 0..4 {
                round_keys[r][c * 4..(c + 1) * 4].copy_from_slice(&w[r * 4 + c]);
            }
        }
        Aes128 { sbox, round_keys }
    }
}

impl Aes128 {
    fn add_round_key(state: &mut [u8; 16], rk: &[u8; 16]) {
        for (s, k) in state.iter_mut().zip(rk.iter()) {
            *s ^= k;
        }
    }

    fn sub_bytes(&self, state: &mut [u8; 16]) {
        for b in state.iter_mut() {
            *b = self.sbox[*b as usize];
        }
    }

    /// State byte order is column-major (byte i sits at row i%4, col i/4).
    fn shift_rows(state: &mut [u8; 16]) {
        let old = *state;
        for r in 1..4 {
            for c in 0..4 {
                state[r + 4 * c] = old[r + 4 * ((c + r) % 4)];
            }
        }
    }

    fn mix_columns(state: &mut [u8; 16]) {
        for c in 0..4 {
            let col = [state[4 * c], state[4 * c + 1], state[4 * c + 2], state[4 * c + 3]];
            state[4 * c] = gmul(col[0], 2) ^ gmul(col[1], 3) ^ col[2] ^ col[3];
            state[4 * c + 1] = col[0] ^ gmul(col[1], 2) ^ gmul(col[2], 3) ^ col[3];
            state[4 * c + 2] = col[0] ^ col[1] ^ gmul(col[2], 2) ^ gmul(col[3], 3);
            state[4 * c + 3] = gmul(col[0], 3) ^ col[1] ^ col[2] ^ gmul(col[3], 2);
        }
    }
}

impl BlockEncrypt for Aes128 {
    fn encrypt_block(&self, block: &mut GenericArray) {
        let state = &mut block.0;
        Self::add_round_key(state, &self.round_keys[0]);
        for r in 1..10 {
            self.sub_bytes(state);
            Self::shift_rows(state);
            Self::mix_columns(state);
            Self::add_round_key(state, &self.round_keys[r]);
        }
        self.sub_bytes(state);
        Self::shift_rows(state);
        Self::add_round_key(state, &self.round_keys[10]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sbox_known_entries() {
        let sbox = build_sbox();
        assert_eq!(sbox[0x00], 0x63);
        assert_eq!(sbox[0x01], 0x7C);
        assert_eq!(sbox[0x53], 0xED);
    }

    #[test]
    fn fips197_appendix_b_vector() {
        // FIPS-197 Appendix B: key 2b7e1516... , plaintext 3243f6a8...
        let key: [u8; 16] = [
            0x2B, 0x7E, 0x15, 0x16, 0x28, 0xAE, 0xD2, 0xA6, 0xAB, 0xF7, 0x15, 0x88, 0x09, 0xCF,
            0x4F, 0x3C,
        ];
        let plain: [u8; 16] = [
            0x32, 0x43, 0xF6, 0xA8, 0x88, 0x5A, 0x30, 0x8D, 0x31, 0x31, 0x98, 0xA2, 0xE0, 0x37,
            0x07, 0x34,
        ];
        let expected: [u8; 16] = [
            0x39, 0x25, 0x84, 0x1D, 0x02, 0xDC, 0x09, 0xFB, 0xDC, 0x11, 0x85, 0x97, 0x19, 0x6A,
            0x0B, 0x32,
        ];
        let cipher = Aes128::new(GenericArray::from_slice(&key));
        let mut block = *GenericArray::from_slice(&plain);
        cipher.encrypt_block(&mut block);
        assert_eq!(block.0, expected);
    }

    #[test]
    fn encryption_is_key_dependent() {
        let c1 = Aes128::new(GenericArray::from_slice(&[1u8; 16]));
        let c2 = Aes128::new(GenericArray::from_slice(&[2u8; 16]));
        let mut b1 = *GenericArray::from_slice(&[0u8; 16]);
        let mut b2 = *GenericArray::from_slice(&[0u8; 16]);
        c1.encrypt_block(&mut b1);
        c2.encrypt_block(&mut b2);
        assert_ne!(b1.0, b2.0);
    }
}
