//! Offline stub for the `xla` crate (xla-rs PJRT bindings).
//!
//! xla-rs is a source-only dependency that links against the XLA C++
//! extension library — neither is available in this offline build
//! environment. This stub keeps the PJRT serving path (`runtime`, `server`,
//! the `serve` subcommand, the secure-KV / LSM examples) COMPILING with the
//! exact API shape those modules use; every entry point that would touch a
//! real PJRT client returns [`Error::Unavailable`] at runtime instead.
//!
//! The simulator, control plane, sweep engine, and all tier-1 tests are
//! pure Rust and never reach this crate at runtime: the server-side tests
//! and examples check for `artifacts/manifest.txt` and skip when kernels
//! were not compiled. To run the serving path for real, replace this path
//! dependency with the actual xla-rs bindings in `rust/Cargo.toml` — no
//! source changes are needed.

use std::fmt;

/// Stub error: the only thing this build can say about PJRT.
#[derive(Debug)]
pub enum Error {
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: PJRT is unavailable in this build (offline xla stub); \
                 install the real xla-rs bindings + XLA extension to run kernels"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// Parsed HLO module handle (never constructible with real contents here).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Computation wrapper.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Matches xla-rs's generic execute over literal-like inputs; the
    /// returned buffers are per-device × per-output.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Host literal.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1(_values: &[u32]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable("Literal::to_tuple1")
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        unavailable("Literal::to_tuple2")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_surfaces_clear_errors() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("offline xla stub"));
        let lit = Literal::vec1(&[1, 2, 3]);
        assert!(lit.reshape(&[3, 1]).is_err());
        assert!(HloModuleProto::from_text_file("nope.hlo.txt").is_err());
    }
}
