//! Offline shim for the `crc32fast` crate: a plain table-driven IEEE CRC32
//! (reflected polynomial 0xEDB88320). No SIMD — the table5 bench that uses
//! this measures a CPU baseline, which this honestly is.

const fn table() -> [u32; 256] {
    let mut t = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        t[i] = c;
        i += 1;
    }
    t
}

/// Table built once at compile time — `hash` in a hot loop pays only the
/// per-byte cost (this backs the table5 CPU-baseline measurement).
const TABLE: [u32; 256] = table();

/// One-shot CRC32 of a buffer.
pub fn hash(buf: &[u8]) -> u32 {
    let mut h = Hasher::new();
    h.update(buf);
    h.finalize()
}

/// Streaming hasher matching crc32fast's surface.
#[derive(Debug, Clone)]
pub struct Hasher {
    state: u32,
}

impl Default for Hasher {
    fn default() -> Self {
        Self::new()
    }
}

impl Hasher {
    pub fn new() -> Hasher {
        Hasher { state: !0 }
    }

    pub fn update(&mut self, buf: &[u8]) {
        let mut c = self.state;
        for &b in buf {
            c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    pub fn finalize(&self) -> u32 {
        !self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard IEEE CRC32 check value.
        assert_eq!(hash(b"123456789"), 0xCBF4_3926);
        assert_eq!(hash(b""), 0);
        assert_eq!(hash(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn streaming_matches_oneshot() {
        let data = b"the quick brown fox jumps over the lazy dog";
        let mut h = Hasher::new();
        h.update(&data[..10]);
        h.update(&data[10..]);
        assert_eq!(h.finalize(), hash(data));
    }
}
